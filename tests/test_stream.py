"""Streaming update engine: delta overlay == rebuild, for every backend.

The contract under test: a ``StreamingIndex`` that absorbed any sequence
of mutations answers EVERY planner backend bit-identically to a
``BitmapIndex`` rebuilt from scratch over the mutated data -- before and
after compaction, sharded and unsharded -- and materialized views stay
fresh while their refresh touches only the mutated tiles.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.bitmaps import unpack
from repro.core.threshold import ALGORITHMS
from repro.query import And, BitmapIndex, Col, Interval, Not, Threshold
from repro.stream import CompactionPolicy, DeltaStore, StreamingIndex

SPAN = 64 * 32  # bits per tile at the default granularity


def _bits(n, r, density=0.2, seed=0):
    rng = np.random.default_rng(seed)
    return rng.random((n, r)) < density


def _names(n):
    return [f"c{i}" for i in range(n)]


def _stream(bits, *, n_shards=None, policy=None):
    names = _names(bits.shape[0])
    idx = BitmapIndex.from_dense(jnp.asarray(bits), names)
    if n_shards:
        idx = idx.shard(n_shards=n_shards)
    return StreamingIndex(idx, policy=policy or CompactionPolicy(auto=False))


def _result(s, q, **kw):
    out = s.execute(q, **kw)
    if hasattr(out, "gather"):
        out = out.gather()
    return np.asarray(unpack(out, s.r))


def _oracle(bits, q, **kw):
    idx = BitmapIndex.from_dense(jnp.asarray(bits), _names(bits.shape[0]))
    return np.asarray(unpack(idx.execute(q, **kw), bits.shape[1]))


def _t_for(alg, n):
    return {"wide_or": 1, "wide_and": n}.get(alg, 3)


# ---------------------------------------------------------------------------
# Update semantics, per mutation kind, vs the rebuild oracle
# ---------------------------------------------------------------------------


class TestMutationKinds:
    N, R = 5, 4 * SPAN + 517  # partial final tile by construction

    def _parity(self, s, bits):
        for q in (Threshold(2), Interval(1, 3), And(Threshold(1), Not(Col("c0")))):
            assert (_result(s, q) == _oracle(bits, q)).all(), q

    def test_set_bits(self):
        bits = _bits(self.N, self.R, seed=1)
        s = _stream(bits)
        pos = [0, 31, 32, SPAN - 1, SPAN, self.R - 1]
        s.set_bits("c1", pos)
        bits = bits.copy()
        bits[1, pos] = True
        self._parity(s, bits)

    def test_clear_bits(self):
        bits = _bits(self.N, self.R, seed=2)
        s = _stream(bits)
        pos = np.arange(100, 4000, 7)
        s.clear_bits("c2", pos)
        bits = bits.copy()
        bits[2, pos] = False
        self._parity(s, bits)

    def test_set_then_clear_idempotence(self):
        """set; clear of the same bits restores the base exactly -- and a
        second identical round changes nothing."""
        bits = _bits(self.N, self.R, seed=3)
        s = _stream(bits)
        pos = [5, 77, SPAN + 3, 3 * SPAN + 100]
        for _ in range(2):
            s.set_bits("c0", pos)
            s.clear_bits("c0", pos)
        # bits that were already set must stay cleared-to-zero only if they
        # started zero; replay the semantics on the oracle side
        mut = bits.copy()
        mut[0, pos] = False
        self._parity(s, mut)

    def test_update_inside_all_zero_and_all_one_tile(self):
        bits = _bits(self.N, self.R, seed=4)
        bits[3, :SPAN] = False  # tile 0 of c3 all-zero
        bits[3, SPAN : 2 * SPAN] = True  # tile 1 of c3 all-one
        s = _stream(bits)
        s.set_bits("c3", [10])  # zero tile gains a bit
        s.clear_bits("c3", [SPAN + 10])  # one tile loses a bit
        mut = bits.copy()
        mut[3, 10] = True
        mut[3, SPAN + 10] = False
        self._parity(s, mut)
        # and back: restore both tiles to clean constants
        s.clear_bits("c3", [10])
        s.set_bits("c3", [SPAN + 10])
        self._parity(s, bits)

    def test_append_rows_crossing_tile_boundary(self):
        """Appended rows fill the partial final tile AND spill into new
        tiles; every column grows, absent bits default to zero."""
        bits = _bits(self.N, self.R, seed=5)
        s = _stream(bits)
        k = (SPAN - self.R % SPAN) + SPAN // 2  # crosses the tile boundary
        app = _bits(self.N, k, density=0.4, seed=6)
        s.append_rows(app)
        assert s.r == self.R + k
        mut = np.concatenate([bits, app], axis=1)
        self._parity(s, mut)

    def test_partial_final_tile_update(self):
        bits = _bits(self.N, self.R, seed=7)
        s = _stream(bits)
        s.set_bits("c4", [self.R - 1, self.R - 17])
        mut = bits.copy()
        mut[4, [self.R - 1, self.R - 17]] = True
        self._parity(s, mut)
        with pytest.raises(ValueError):
            s.set_bits("c4", [self.R])  # outside the universe

    def test_compaction_preserves_results_and_is_tile_granular(self):
        bits = _bits(self.N, self.R, seed=8)
        s = _stream(bits)
        s.set_bits("c0", [3, SPAN + 3])
        mut = bits.copy()
        mut[0, [3, SPAN + 3]] = True
        before = _result(s, Threshold(2))
        base_store = s._base.store
        assert s.compact() is True
        # untouched columns share their classified tiles with the old base
        assert s._base.store._cols[1] is base_store._cols[1]
        assert s.delta_words == 0
        assert (_result(s, Threshold(2)) == before).all()
        self._parity(s, mut)


# ---------------------------------------------------------------------------
# The acceptance sweep: 1k random single-bit updates, every backend,
# pre/post compaction, sharded and unsharded
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_shards", [None, 4])
def test_1k_random_updates_every_backend_matches_rebuild(n_shards):
    n, r = 6, 8 * SPAN + 321
    bits = _bits(n, r, seed=11)
    s = _stream(bits, n_shards=n_shards)
    rng = np.random.default_rng(12)
    mut = bits.copy()
    cols = rng.integers(0, n, 1000)
    pos = rng.integers(0, r, 1000)
    on = rng.random(1000) < 0.5
    # dedupe to the LAST write per (col, pos): update() applies all sets
    # before all clears, so a colliding draw would otherwise make the
    # sequential numpy oracle and the batched apply legitimately disagree
    last = {(int(c), int(p)): bool(o) for c, p, o in zip(cols, pos, on)}
    sets: dict = {}
    clears: dict = {}
    for (c, p), o in last.items():
        (sets if o else clears).setdefault(f"c{c}", []).append(p)
        mut[c, p] = o
    s.update(sets=sets, clears=clears)

    def check(tag):
        for alg in ALGORITHMS:
            t = _t_for(alg, n)
            got = _result(s, Threshold(t), backend=alg)
            want = _oracle(mut, Threshold(t), backend=alg)
            assert (got == want).all(), (tag, alg)

    check("pre-compaction")
    assert s.compact() is True
    check("post-compaction")


def test_stale_overlay_index_is_a_consistent_snapshot():
    """An overlay index captured with a live delta must keep answering AS
    OF that instant, identically on every backend, after further
    mutations land -- the stale-reference guarantee extends to overlays."""
    bits = _bits(4, 3 * SPAN + 99, seed=41)
    s = _stream(bits)
    s.set_bits("c0", [7])
    mut_then = bits.copy()
    mut_then[0, 7] = True
    stale = s.index()  # overlay over base + the one-bit delta
    s.clear_bits("c1", np.arange(0, 2000))  # later mutation, same delta store
    for backend in ("fused", "tiled_fused", "ssum", "scancount"):
        got = np.asarray(unpack(stale.execute(Threshold(2), backend=backend),
                                stale.store.r))
        want = _oracle(mut_then, Threshold(2), backend=backend)
        assert (got == want).all(), backend
    # planner statistics describe the same instant too
    exp = BitmapIndex.from_dense(jnp.asarray(mut_then), _names(4))
    assert stale.store.cardinalities == exp.store.cardinalities


def test_overlay_planner_sees_mutated_stats():
    """The planner prices the OVERLAID data: dirtying a clean index's tiles
    must be visible in the member statistics it plans from."""
    bits = np.zeros((4, 8 * SPAN), bool)
    bits[:, :7] = True  # one dirty tile, rest all-zero
    s = _stream(bits)
    clean_stats = s.index().store.member_stats(None)
    rng = np.random.default_rng(0)
    for c in range(4):
        s.set_bits(f"c{c}", rng.integers(0, 8 * SPAN, 2000))
    dirty_stats = s.index().store.member_stats(None)
    assert dirty_stats.clean_fraction < clean_stats.clean_fraction
    assert dirty_stats.dirty_words > clean_stats.dirty_words
    plan = s.explain(Threshold(2))
    assert plan.algorithm in ALGORITHMS + ("circuit",)


# ---------------------------------------------------------------------------
# Materialized views
# ---------------------------------------------------------------------------


class TestMaterializedViews:
    N, R = 6, 6 * SPAN + 123

    def _fresh(self, s, mut, lo=2, hi=4):
        counts = mut.sum(0)
        want = (counts >= lo) & (counts <= hi)
        col = s.column("mid")
        if hasattr(col, "gather"):
            col = col.gather()
        assert (np.asarray(unpack(col, s.r)) == want).all()
        assert s.count(Col("mid")) == int(want.sum())

    def test_freshness_after_each_mutation_kind(self):
        bits = _bits(self.N, self.R, seed=21)
        s = _stream(bits)
        s.materialize("mid", Interval(2, 4))
        mut = bits.copy()
        self._fresh(s, mut)
        # set
        s.set_bits("c0", [9, SPAN + 9])
        mut[0, [9, SPAN + 9]] = True
        self._fresh(s, mut)
        # clear
        s.clear_bits("c1", np.arange(0, 2000, 3))
        mut[1, np.arange(0, 2000, 3)] = False
        self._fresh(s, mut)
        # set-then-clear back
        s.set_bits("c2", [42])
        s.clear_bits("c2", [42])
        mut[2, 42] = False
        self._fresh(s, mut)
        # append crossing a tile boundary
        k = SPAN
        app = _bits(self.N, k, density=0.5, seed=22)
        s.append_rows(app)
        mut = np.concatenate([mut, app], axis=1)
        self._fresh(s, mut)
        # compaction keeps the view column and its count
        assert s.compact() is True
        self._fresh(s, mut)

    def test_refresh_touches_only_mutated_tiles(self):
        """The words-touched counter: refresh work scales with the mutated
        tiles, never the universe."""
        bits = _bits(self.N, self.R, seed=23)
        s = _stream(bits)
        s.materialize("mid", Interval(2, 4))
        s.set_bits("c3", [2 * SPAN + 5])  # exactly one tile touched
        s.refresh()
        info = s.view_info("mid")
        assert info["tiles_refreshed"] == 1
        tw = s.tile_words
        # at most the support columns' words for ONE tile + the output write
        assert info["words_touched"] <= (self.N + 1) * tw
        full_sweep = (self.N + 1) * s.index().store.n_tiles * tw
        assert info["words_touched"] < full_sweep / 4
        # an untouched query leaves nothing pending
        s.refresh()
        assert s.view_info("mid")["tiles_refreshed"] == 1  # unchanged record

    def test_view_binds_member_set_at_registration(self):
        """over=None means "all columns NOW": adding the view column (or a
        later view) must not change an existing view's member set."""
        bits = _bits(3, 2 * SPAN, seed=24)
        s = _stream(bits)
        s.materialize("two", Threshold(2))
        s.materialize("any", Threshold(1))  # second view; schema now 5 wide
        counts = bits.sum(0)
        for name, want in (("two", counts >= 2), ("any", counts >= 1)):
            col = s.column(name)
            assert (np.asarray(unpack(col, s.r)) == want).all(), name
            assert s.count(Col(name)) == int(want.sum())

    def test_view_over_view_chains(self):
        bits = _bits(4, 2 * SPAN + 77, seed=25)
        s = _stream(bits)
        s.materialize("two", Threshold(2))
        s.materialize("promo", And(Col("two"), Col("c0")))
        mut = bits.copy()
        s.set_bits("c1", [5, SPAN + 5])
        mut[1, [5, SPAN + 5]] = True
        counts = mut.sum(0)
        want = (counts >= 2) & mut[0]
        col = s.column("promo")
        assert (np.asarray(unpack(col, s.r)) == want).all()
        assert s.count(Col("promo")) == int(want.sum())

    def test_view_with_true_at_zero_weight_masks_padding(self):
        """A truth table with f(0)=1 (Interval(0, 1)) must not leak set
        bits past r into the partial final tile -- the popcount-delta
        count would silently drift."""
        r = SPAN + 100  # partial final tile
        bits = _bits(3, r, seed=29)
        s = _stream(bits)
        s.materialize("mid", Interval(0, 1))
        mut = bits.copy()
        s.set_bits("c0", [r - 1])
        mut[0, r - 1] = True
        counts = mut.sum(0)
        want = counts <= 1
        assert (np.asarray(unpack(s.column("mid"), s.r)) == want).all()
        assert s.count(Col("mid")) == int(want.sum())

    def test_constant_view_extends_over_appended_rows(self):
        """A query that folds to a constant has EMPTY circuit support --
        append_rows must still refresh it over the new rows (regression:
        it stayed all-zero there forever)."""
        r = SPAN + 40
        bits = _bits(3, r, seed=30)
        s = _stream(bits)
        s.materialize("always", Threshold(0))  # constant-true
        assert s.count(Col("always")) == r
        s.append_rows(_bits(3, 60, seed=31))
        assert s.r == r + 60
        assert s.count(Col("always")) == r + 60
        col = s.column("always")
        assert (np.asarray(unpack(col, s.r)) == True).all()  # noqa: E712
        s.compact()
        assert s.count(Col("always")) == r + 60

    def test_views_cannot_be_mutated_directly(self):
        bits = _bits(3, SPAN, seed=26)
        s = _stream(bits)
        s.materialize("mid", Interval(1, 2))
        with pytest.raises(ValueError):
            s.set_bits("mid", [0])

    def test_sharded_view_freshness(self):
        bits = _bits(self.N, self.R, seed=27)
        s = _stream(bits, n_shards=3)
        s.materialize("mid", Interval(2, 4))
        mut = bits.copy()
        rng = np.random.default_rng(28)
        pos = rng.integers(0, self.R, 64)
        s.set_bits("c4", pos)
        mut[4, pos] = True
        self._fresh(s, mut)
        info = s.view_info("mid")
        assert info["tiles_refreshed"] <= np.unique(pos // SPAN).size


# ---------------------------------------------------------------------------
# Compaction policy
# ---------------------------------------------------------------------------


def test_auto_compaction_policy_triggers():
    bits = _bits(4, 8 * SPAN, density=0.01, seed=31)
    s = _stream(
        bits,
        policy=CompactionPolicy(min_delta_words=2 * 64, max_delta_ratio=0.0),
    )
    assert s.compactions == 0
    s.set_bits("c0", [0])  # one tile: 64 words < threshold
    assert s.compactions == 0 and s.delta_words > 0
    s.set_bits("c1", [0, SPAN, 2 * SPAN])  # pushes past min_delta_words
    assert s.compactions == 1
    assert s.delta_words == 0


def test_compact_noop_when_empty():
    s = _stream(_bits(2, SPAN, seed=32))
    assert s.compact() is False


# ---------------------------------------------------------------------------
# DeltaStore unit behaviour
# ---------------------------------------------------------------------------


def test_delta_store_patch_and_popcount_delta():
    bits = np.zeros((2, 2 * SPAN), bool)
    bits[1, :SPAN] = True
    store = BitmapIndex.from_dense(jnp.asarray(bits), ["a", "b"]).store
    d = DeltaStore(store)
    assert d.empty
    t = d.set_bits(0, [3, 35])
    assert t == [0] and not d.empty
    assert d.card_delta(0) == 2
    words = np.zeros(64, np.uint32)
    words[0] = 0b1
    delta = d.patch_tile(0, 0, words)
    assert delta == -1  # 2 bits -> 1 bit
    assert d.card_delta(0) == 1
    # clearing the all-one tile of column b
    d.clear_bits(1, [7])
    assert d.card_delta(1) == -1
    assert d.delta_words == 2 * 64


# ---------------------------------------------------------------------------
# Schema growth (add_data_column) + append_rows row ranges
# ---------------------------------------------------------------------------


class TestSchemaGrowth:
    @pytest.mark.parametrize("n_shards", [None, 3])
    def test_add_data_column_then_mutate(self, n_shards):
        bits = _bits(4, 2 * SPAN + 100, seed=17)
        s = _stream(bits, n_shards=n_shards)
        assert "c9" not in s
        s.add_data_column("c9")
        assert "c9" in s and s.count(Col("c9")) == 0
        # a fresh column participates in every mutation kind
        rows = [0, SPAN + 5, s.r - 1]
        s.update(sets={"c9": rows})
        assert _result(s, Col("c9")).nonzero()[0].tolist() == sorted(rows)
        oracle = np.concatenate([bits, np.zeros((1, bits.shape[1]), bool)])
        oracle[4, rows] = True
        got = _result(s, Threshold(2, over=[Col("c0"), Col("c1"), Col("c9")]))
        want = _oracle(oracle, Threshold(2, over=[Col("c0"), Col("c1"), Col("c4")]))
        np.testing.assert_array_equal(got, want)

    def test_add_data_column_with_payload(self):
        bits = _bits(3, SPAN + 40, seed=18)
        s = _stream(bits)
        payload = np.zeros(s.index().n_words, np.uint32)
        payload[0] = 0b1011
        s.add_data_column("extra", payload)
        assert s.count(Col("extra")) == 3
        assert _result(s, Col("extra")).nonzero()[0].tolist() == [0, 1, 3]

    def test_add_data_column_validation(self):
        s = _stream(_bits(2, 200, seed=19))
        with pytest.raises(ValueError):
            s.add_data_column("c0")  # duplicate

    def test_add_data_column_flushes_pending_appends(self):
        """Schema growth compacts first: pending appends live in a read-only
        overlay that cannot grow columns, and must not be lost."""
        bits = _bits(3, 300, seed=20)
        s = _stream(bits)
        s.append_rows({"c0": np.ones(40, bool)})
        s.add_data_column("late")
        assert s.r == 340 and s.count(Col("c0")) == int(bits[0].sum()) + 40
        assert s.count(Col("late")) == 0

    def test_append_rows_returns_row_range(self):
        bits = _bits(2, 150, seed=21)
        s = _stream(bits)
        assert s.append_rows({}) == (150, 150)
        start, stop = s.append_rows({"c1": np.array([True, False, True])})
        assert (start, stop) == (150, 153)
        assert _result(s, Col("c1")).nonzero()[0].tolist() == sorted(
            np.nonzero(bits[1])[0].tolist() + [150, 152]
        )
        start, stop = s.append_rows({"c0": np.ones(5, bool)})
        assert (start, stop) == (153, 158)
