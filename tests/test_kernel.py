"""Pallas kernel: shape/dtype sweep against the pure-jnp oracle (interpret)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import fused_interval, fused_symmetric, fused_threshold
from repro.kernels.ref import symmetric_ref, threshold_ref
from repro.kernels.threshold_ssum import pick_block_words, threshold_pallas


def _bm(n, nw, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**32, (n, nw), dtype=np.uint32))


@pytest.mark.parametrize("n", [2, 3, 5, 16, 64, 130])
@pytest.mark.parametrize("nw", [1, 7, 100, 1030])
def test_threshold_kernel_shape_sweep(n, nw):
    bm = _bm(n, nw, seed=n * 1000 + nw)
    for t in sorted({1, 2, n // 2, n}):
        got = np.asarray(fused_threshold(bm, t, block_words=256))
        exp = np.asarray(threshold_ref(bm, t))
        np.testing.assert_array_equal(got, exp, err_msg=f"n={n} nw={nw} t={t}")


@pytest.mark.parametrize("block_words", [128, 1024, 4096])
def test_threshold_kernel_block_sizes(block_words):
    bm = _bm(33, 2050, seed=9)
    got = np.asarray(fused_threshold(bm, 11, block_words=block_words))
    np.testing.assert_array_equal(got, np.asarray(threshold_ref(bm, 11)))


def test_symmetric_kernel():
    rng = np.random.default_rng(4)
    for n in (4, 9, 31):
        bm = _bm(n, 300, seed=n)
        truth = tuple(bool(x) for x in rng.integers(0, 2, n + 1))
        got = np.asarray(fused_symmetric(bm, truth, block_words=256))
        np.testing.assert_array_equal(got, np.asarray(symmetric_ref(bm, truth)))


def test_interval_kernel():
    bm = _bm(12, 129, seed=5)
    got = np.asarray(fused_interval(bm, 3, 7))
    exp = np.asarray(symmetric_ref(bm, tuple(3 <= w <= 7 for w in range(13))))
    np.testing.assert_array_equal(got, exp)


def test_treeadd_kernel_variant():
    bm = _bm(21, 500, seed=6)
    got = np.asarray(threshold_pallas(bm, 9, kind="treeadd", interpret=True))
    np.testing.assert_array_equal(got, np.asarray(threshold_ref(bm, 9)))


def test_pick_block_words_vmem_budget():
    # block must shrink as N grows to hold the working set in VMEM
    small_n = pick_block_words(8, 1 << 20)
    large_n = pick_block_words(512, 1 << 20)
    assert small_n >= large_n
    assert large_n >= 1024  # lane-aligned floor
    # working set (2 rows live per input) fits the 4 MiB default budget
    assert 2 * 512 * large_n * 4 <= 4 * 1024 * 1024 + 512 * 1024


def test_kernel_matches_all_jnp_algorithms():
    from repro.core.threshold import threshold

    bm = _bm(17, 200, seed=8)
    fused = np.asarray(threshold(bm, 6, "fused"))
    for alg in ("scancount", "ssum", "looped", "csvckt"):
        np.testing.assert_array_equal(fused, np.asarray(threshold(bm, 6, alg)))
