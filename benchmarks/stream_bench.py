"""Streaming update engine benchmark: delta apply + query vs full rebuild.

What the ``repro.stream`` subsystem buys at serving time:

  * **update + query latency**: absorb a batch of random single-bit
    updates (1% of the universe by default) into a ``StreamingIndex`` and
    answer a threshold query through the delta overlay, vs rebuilding a
    ``BitmapIndex`` from the mutated bitmaps (tile classification +
    build-time statistics) and querying that -- the only option the
    immutable index offers.  The acceptance bar is >=10x at a 1% mutation
    rate.
  * **materialized-view refresh**: per-update-batch cost of keeping the
    abstract's "on sale in 2 to 10 stores" result fresh, vs re-executing
    the query from scratch; plus the words actually touched.
  * **compaction amortization curve**: compaction wall time as the delta
    grows (1 .. many update batches between compactions), and the
    query-after-compaction time showing the overlay bookkeeping being
    folded back to baseline.

Writes ``BENCH_stream.json`` (uploaded by CI next to ``BENCH_query.json``)
and prints the usual ``name,value,extra`` CSV lines.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.query import BitmapIndex, Interval, Threshold
from repro.stream import CompactionPolicy, StreamingIndex

MUTATION_RATES = (0.001, 0.01, 0.05)


def _time(fn, reps=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def _clean_heavy_bits(n, n_tiles, seed=0, span=64 * 32, clean=0.9):
    """Row-correlated clean-heavy data: a tile range is quiet (all-zero) or
    saturated (all-one) for EVERY column, else dirty -- the product-range
    structure streaming corpora actually have, and what keeps the tile
    signature count (and thus the tiled planner estimate) bounded."""
    rng = np.random.default_rng(seed)
    bits = np.zeros((n, n_tiles * span), bool)
    for tj in range(n_tiles):
        u = rng.random()
        lo, hi = tj * span, (tj + 1) * span
        if u < clean / 2:
            pass
        elif u < clean:
            bits[:, lo:hi] = True
        else:
            bits[:, lo:hi] = rng.random((n, span)) < 0.35
    return bits


def _mutations(rng, n, r, k, *, lo=0, hi=None):
    """k single-bit updates over columns x positions in [lo, hi); deduped
    to the LAST write per (column, position) so a batched apply (sets then
    clears) and a sequential replay agree."""
    hi = r if hi is None else hi
    cols = rng.integers(0, n, k)
    pos = rng.integers(lo, hi, k)
    on = rng.random(k) < 0.5
    last = {int(c) * r + int(p): i for i, (c, p) in enumerate(zip(cols, pos))}
    sel = np.asarray(sorted(last.values()))
    return cols[sel], pos[sel], on[sel]


def _clean_heavy_packed(n, n_tiles, seed=0, tw=64, clean=0.95):
    """Packed-word variant of :func:`_clean_heavy_bits` -- builds the
    uint32 columns directly so the large bench shapes never materialise a
    boolean [n, r] array (8x the memory)."""
    rng = np.random.default_rng(seed)
    words = np.zeros((n, n_tiles * tw), np.uint32)
    for tj in range(n_tiles):
        u = rng.random()
        lo, hi = tj * tw, (tj + 1) * tw
        if u < clean / 2:
            pass
        elif u < clean:
            words[:, lo:hi] = 0xFFFFFFFF
        else:
            # ~0.25 bit density: AND of two uniform word draws
            words[:, lo:hi] = rng.integers(
                0, 1 << 32, (n, tw), dtype=np.uint32
            ) & rng.integers(0, 1 << 32, (n, tw), dtype=np.uint32)
    return words


def _apply_packed(packed, cols, pos, on):
    """The deduped update batch applied to packed words (last write wins
    already guaranteed by :func:`_mutations`)."""
    out = packed.copy()
    w = cols * packed.shape[1] + pos // 32
    b = (np.uint32(1) << (pos % 32).astype(np.uint32))
    flat = out.reshape(-1)
    np.bitwise_or.at(flat, w[on], b[on])
    np.bitwise_and.at(flat, w[~on], ~b[~on])
    return out


def update_vs_rebuild(smoke: bool = False) -> list:
    """update+query latency: streaming engine vs from-scratch rebuild.

    The serving pattern under test: a registered query (here the
    Threshold(N/2) production selection, kept as a materialized view) must
    stay answerable while single-bit updates stream in.  The streaming
    engine absorbs the batch into the delta and refreshes the view over
    ONLY the mutated tiles; the immutable index's only alternative is a
    full rebuild -- re-classify and re-upload every column, re-execute the
    query -- before it can answer at all.

    The primary series follows Roaring's container-local update model:
    mutations churn inside a hot window (1% of the row space), the
    realistic steady state.  A uniform-random series (the delta smeared
    across every tile -- the overlay's worst case) is reported alongside
    for honesty; there the live :class:`CompactionPolicy` folds the delta
    mid-update, which is the designed response.  An ad-hoc (non-view)
    overlay execute is timed too, so the artifact separates "incremental
    view serving" from "plain query through the overlay".
    """
    n, n_tiles = (8, 256) if smoke else (128, 4096)
    packed = _clean_heavy_packed(n, n_tiles, seed=3, clean=0.95)
    r = packed.shape[1] * 32
    names = [f"c{i}" for i in range(n)]
    q = Threshold(n // 2)
    rng = np.random.default_rng(7)
    out = []
    hot = max(64 * 32, int(0.01 * r))
    runs = [("hot_window", rate, 0, hot) for rate in MUTATION_RATES]
    runs.append(("uniform", 0.01, 0, r))
    for dist, rate, lo, hi in runs:
        k = max(1, int(r * rate))
        cols, pos, on = _mutations(rng, n, r, k, lo=lo, hi=hi)
        packed_mutated = _apply_packed(packed, cols, pos, on)

        # the serving steady state: index + registered view exist before
        # the updates arrive; time ONLY absorb + answer
        base = StreamingIndex(BitmapIndex(packed, names, r=r))
        base.materialize("live", q)
        sets = {names[c]: pos[on & (cols == c)] for c in range(n) if (on & (cols == c)).any()}
        clears = {names[c]: pos[~on & (cols == c)] for c in range(n) if (~on & (cols == c)).any()}

        def stream_update_count(s=base):
            s.update(sets=sets, clears=clears)
            return s.count("live")

        def stream_update_adhoc(s=base):
            s.update(sets=sets, clears=clears)
            return np.asarray(s.execute(q))

        def rebuild_count():
            return BitmapIndex(packed_mutated, names, r=r).count(q)

        t_stream = _time(stream_update_count)
        t_adhoc = _time(stream_update_adhoc)
        t_rebuild = _time(rebuild_count)
        # parity guard: the bench only counts if the answers agree
        assert stream_update_count() == rebuild_count()
        assert (
            stream_update_adhoc()
            == np.asarray(BitmapIndex(packed_mutated, names, r=r).execute(q))
        ).all()
        info = base.view_info("live") or {}
        out.append(
            {
                "distribution": dist,
                "mutation_rate": rate,
                "updates": k,
                "r": r,
                "n": n,
                "stream_update_query_us": t_stream * 1e6,
                "stream_adhoc_query_us": t_adhoc * 1e6,
                "rebuild_query_us": t_rebuild * 1e6,
                "speedup": t_rebuild / t_stream,
                "view_tiles_refreshed": info.get("tiles_refreshed", 0),
                "view_words_touched": info.get("words_touched", 0),
                "n_tiles": n_tiles,
                "delta_words": base.delta_words,
                "compactions": base.compactions,
            }
        )
    return out


def view_refresh(smoke: bool = False) -> list:
    """Materialized-view maintenance vs re-executing the query."""
    n, n_tiles = (8, 16) if smoke else (12, 64)
    bits = _clean_heavy_bits(n, n_tiles, seed=5)
    r = bits.shape[1]
    names = [f"store{i}" for i in range(n)]
    s = StreamingIndex.from_dense(
        jnp.asarray(bits), names, policy=CompactionPolicy(auto=False)
    )
    q = Interval(2, min(10, n - 1))
    s.materialize("mid", q)
    rng = np.random.default_rng(9)
    out = []
    for batch in (1, 8, 64):
        cols, pos, on = _mutations(rng, n, r, batch)

        def mutate_and_read():
            s.update(
                sets={names[c]: [int(p)] for c, p, o in zip(cols, pos, on) if o},
                clears={names[c]: [int(p)] for c, p, o in zip(cols, pos, on) if not o},
            )
            s.refresh()
            return s.count("mid")

        t_view = _time(mutate_and_read)
        t_reexec = _time(lambda: int(s.count(q)))
        info = s.view_info("mid") or {}
        out.append(
            {
                "update_batch": batch,
                "view_update_read_us": t_view * 1e6,
                "reexecute_us": t_reexec * 1e6,
                "tiles_refreshed": info.get("tiles_refreshed", 0),
                "words_touched": info.get("words_touched", 0),
                "total_words": n * s.index().store.n_words,
            }
        )
    return out


def compaction_curve(smoke: bool = False) -> list:
    """Compaction cost as the delta grows + query time after compaction."""
    n, n_tiles = (8, 16) if smoke else (16, 64)
    bits = _clean_heavy_bits(n, n_tiles, seed=11)
    r = bits.shape[1]
    names = [f"c{i}" for i in range(n)]
    q = Threshold(n // 2)
    rng = np.random.default_rng(13)
    out = []
    for batches in (1, 4, 16):
        s = StreamingIndex.from_dense(
            jnp.asarray(bits), names, policy=CompactionPolicy(auto=False)
        )
        k = max(1, r // 1000)
        for _ in range(batches):
            cols, pos, on = _mutations(rng, n, r, k)
            s.update(
                sets={names[c]: [int(p)] for c, p, o in zip(cols, pos, on) if o},
                clears={names[c]: [int(p)] for c, p, o in zip(cols, pos, on) if not o},
            )
        dw = s.delta_words
        t0 = time.perf_counter()
        s.compact()
        t_compact = time.perf_counter() - t0
        t_query = _time(lambda: np.asarray(s.execute(q)))
        out.append(
            {
                "update_batches": batches,
                "delta_words_at_compaction": dw,
                "compact_us": t_compact * 1e6,
                "query_after_compact_us": t_query * 1e6,
                "amortized_us_per_batch": t_compact * 1e6 / batches,
            }
        )
    return out


def run(smoke: bool = False, payload: dict | None = None) -> list:
    if payload is None:
        payload = collect(smoke)
    out = []
    for row in payload["update_vs_rebuild"]:
        tag = f"stream_{row['distribution']}_m{row['mutation_rate']}"
        out.append(
            (
                f"{tag}_update_query_us",
                row["stream_update_query_us"],
                f"{row['updates']} single-bit updates",
            )
        )
        out.append((f"{tag}_rebuild_us", row["rebuild_query_us"], ""))
        out.append((f"{tag}_speedup", row["speedup"], ">=10x target at 1% hot"))
    for row in payload["view_refresh"]:
        out.append(
            (
                f"stream_view_b{row['update_batch']}_us",
                row["view_update_read_us"],
                f"{row['tiles_refreshed']} tiles, {row['words_touched']} words",
            )
        )
    for row in payload["compaction"]:
        out.append(
            (
                f"stream_compact_b{row['update_batches']}_us",
                row["compact_us"],
                f"{row['delta_words_at_compaction']} delta words",
            )
        )
    return out


def collect(smoke: bool = False) -> dict:
    return {
        "bench": "stream",
        "smoke": bool(smoke),
        "n_devices": len(jax.devices()),
        "update_vs_rebuild": update_vs_rebuild(smoke),
        "view_refresh": view_refresh(smoke),
        "compaction": compaction_curve(smoke),
    }


def write_json(path: str = "BENCH_stream.json", smoke: bool = False,
               payload: dict | None = None) -> dict:
    if payload is None:
        payload = collect(smoke)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return payload


if __name__ == "__main__":
    import sys

    smoke = "--smoke" in sys.argv
    payload = collect(smoke)
    for name, val, extra in run(smoke, payload=payload):
        print(f"{name},{val:.2f},{extra}")
    write_json(smoke=smoke, payload=payload)
    for row in payload["update_vs_rebuild"]:
        print(
            f"{row['distribution']} mutation_rate={row['mutation_rate']}: stream "
            f"{row['stream_update_query_us']:.0f}us vs rebuild "
            f"{row['rebuild_query_us']:.0f}us ({row['speedup']:.1f}x, "
            f"{row['view_tiles_refreshed']}/{row['n_tiles']} tiles refreshed)"
        )
    print("wrote BENCH_stream.json")
