"""Benchmark driver: one section per paper table/figure.

Prints ``name,value,derived`` CSV lines.  Sections:
  table5   -- row scan vs bitmap index (paper Table 5)
  table7   -- circuit gate counts vs Tables 6/7/8 (paper-faithfulness check)
  fig3     -- scaling with N and T (paper Figs 3/4)
  table10  -- workload ranking across algorithm families (paper 5.9)
  heatmap  -- SMALL-COMPETITIONS win/terrible rates (paper 5.8, App. C)
  weighted -- weighted thresholds: replication vs binary decomposition
  kernel   -- fused Pallas kernel traffic model + jnp wall-times
  query    -- unified query API: composed-circuit vs leafwise, batching,
              compiled-circuit cache (repro.query)
  stream   -- streaming update engine: delta apply + view refresh vs full
              rebuild, compaction amortization (repro.stream; smoke sizes)
  persist  -- on-disk format: snapshot size vs density, cold-load-to-
              first-query vs rebuild, WAL replay throughput (repro.persist;
              scratch snapshots in a temp dir, removed on exit)
  serve    -- multi-client serving front-end: coalesced QPS vs sequential
              across client counts, cache/dedup/shed rates, batch-size
              histogram, plan-memo + calibration counters (repro.serve;
              smoke sizes, writes BENCH_serve.json)
  obs      -- observability overhead: metrics+tracing ON vs OFF per-query
              cost, disabled-site cost, drift sample counts, Prometheus
              scrape lint (repro.obs; writes BENCH_obs.json and the
              BENCH_obs_trace.jsonl span-tree artifact)
  search   -- similarity search + windowed analytics: bitmap candidate
              generation raced vs the integer-list competitors (MergeOpt /
              DivideSkip / WHEAP) at the same T, adaptive top-k, window
              refresh words-touched vs the touched-tiles bound
              (repro.search; smoke sizes, writes BENCH_search.json)
  roofline -- three-term roofline per dry-run cell (deliverable g; requires
              artifacts/dryrun from ``python -m repro.launch.dryrun``)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    sections = sys.argv[1:] or ["table5", "table7", "fig3", "table10", "heatmap", "kernel", "weighted", "query", "stream", "persist", "serve", "obs", "search", "roofline"]
    failures = 0
    for section in sections:
        print(f"# --- {section} ---")
        try:
            if section == "table5":
                from benchmarks import table5_rowscan as mod

                rows = mod.run()
            elif section == "table7":
                from benchmarks import table7_gates as mod

                rows = mod.run()
            elif section == "fig3":
                from benchmarks import fig3_scaling as mod

                rows = mod.run()
            elif section == "table10":
                from benchmarks import table10_workload as mod

                rows = mod.run()
            elif section == "kernel":
                from benchmarks import kernel_bench as mod

                rows = mod.run()
            elif section == "heatmap":
                from benchmarks import heatmap_competitions as mod

                rows = mod.run()
            elif section == "weighted":
                from benchmarks import weighted_bench as mod

                rows = mod.run()
            elif section == "query":
                from benchmarks import query_bench as mod

                rows = mod.run()
            elif section == "stream":
                from benchmarks import stream_bench as mod

                rows = mod.run(smoke=True)
            elif section == "persist":
                from benchmarks import persist_bench as mod

                rows = mod.run(smoke=True)
            elif section == "serve":
                from benchmarks import serve_bench as mod

                rows = mod.run(smoke=True)
            elif section == "obs":
                from benchmarks import obs_bench as mod

                rows = mod.run(smoke=True)
            elif section == "search":
                from benchmarks import search_bench as mod

                rows = mod.run(smoke=True)
            elif section == "roofline":
                from benchmarks import roofline as mod

                rows, table = mod.run()
                if table:
                    print(f"# roofline table -> {mod.write_markdown(table)}")
            else:
                raise ValueError(f"unknown section {section}")
            for name, val, extra in rows:
                print(f"{name},{val if isinstance(val, int) else round(float(val), 3)},{extra}")
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
