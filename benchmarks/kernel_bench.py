"""Kernel-level benchmarks: the single-scan tiled engine vs the dense fused
kernel, plus the legacy fused-vs-composed comparison.

Two sections, both written into ``BENCH_kernel.json`` (uploaded as a CI
artifact so the perf trajectory is inspectable per push):

  * ``crossover`` -- tiled_fused (scan engine: in-kernel container decode,
    O(1) dispatches) vs ``fused`` wall time across clean-fraction and
    density sweep points, with launches-per-query and the planner's
    words-touched estimates.  The acceptance contract: wherever the words
    model predicts a tiled win (``est_tiled < _TILED_ADVANTAGE *
    est_fused``) on a traffic-bound point, measured tiled wall time must
    beat fused, with O(1) launches (see ``tiled_crossover`` for the
    CPU-scatter caveat on the densest sparse points).

  * ``legacy`` -- fused Pallas threshold vs composed-jnp circuit vs
    SCANCOUNT: wall time of the XLA-compiled paths, the analytic
    HBM-traffic model for TPU, and the VMEM working set of the chosen
    BlockSpec (unchanged from the original bench; on CPU the Pallas
    kernel runs in interpret mode, so its own wall time is a lower bound
    only for the XLA-emulated path).

``--smoke`` runs tiny shapes for CI and additionally asserts the collapsed
launch count: a batched multi-residual query (which on the per-group path
launched once per structurally distinct residual) must report
``info["launches"] <= 2``.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import circuits as C
from repro.core.threshold import threshold
from repro.kernels.threshold_ssum import pick_block_words

REPO = pathlib.Path(__file__).resolve().parent.parent


def _time(fn, reps=5):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def hbm_model(n: int, t: int, n_words: int) -> dict:
    """Bytes moved to/from HBM per threshold query (TPU model)."""
    gates = C.build_threshold_circuit(n, t, "ssum").gate_count()
    word_bytes = 4
    fused = (n + 1) * n_words * word_bytes  # stream in N planes, write 1
    # composed jnp: every gate reads 2 planes and writes 1 (upper bound; XLA
    # fusion recovers some, but bit-plane intermediates exceed cache at this r)
    composed = (3 * gates) * n_words * word_bytes
    return {"fused_bytes": fused, "composed_bytes": composed, "ratio": composed / fused}


def _clean_fraction_bits(n, n_tiles, clean_fraction, seed=0, span=64 * 32):
    rng = np.random.default_rng(seed)
    bits = np.zeros((n, n_tiles * span), bool)
    for i in range(n):
        for tj in range(n_tiles):
            u = rng.random()
            lo, hi = tj * span, (tj + 1) * span
            if u < clean_fraction / 2:
                pass
            elif u < clean_fraction:
                bits[i, lo:hi] = True
            else:
                bits[i, lo:hi] = rng.random(span) < 0.35
    return bits


def tiled_crossover(smoke: bool = False) -> list:
    """tiled_fused (scan engine) vs fused: wall time, launches, words model.

    ``assert_win`` marks the rows where the measured backend is expected to
    be traffic-bound, so a words-model win must show up as a wall-time win:
    every clean-fraction point, and density points at or below 1e-4 on CPU
    (XLA CPU scatters cost ~80 ns/toggle, which makes the sparse event
    path compute-bound above ~3e-4 density there; on accelerators the
    traffic model governs the whole sweep).
    """
    from repro.core.planner import _TILED_ADVANTAGE, estimate_words_touched
    from repro.query import BitmapIndex, Threshold

    cpu = jax.default_backend() == "cpu"
    n = 8
    n_tiles = 8 if smoke else 2048
    span = 64 * 32
    points = [("clean_fraction", cf) for cf in (0.0, 0.5, 0.9, 0.99)]
    points += [("density", d) for d in (1e-5, 1e-4, 1e-3)]
    rows = []
    for kind, param in points:
        if kind == "clean_fraction":
            bits = _clean_fraction_bits(n, n_tiles, param, seed=int(param * 100) + 1)
        else:
            rng = np.random.default_rng(int(param * 1e6) + 7)
            bits = rng.random((n, n_tiles * span)) < param
        idx = BitmapIndex.from_dense(jnp.asarray(bits))
        q = Threshold(n // 2)
        t_fused = _time(
            lambda: idx.execute(q, backend="fused").block_until_ready()
        )
        t_tiled = _time(
            lambda: idx.execute(q, backend="tiled_fused").block_until_ready()
        )
        info = idx.last_info
        stats = idx.store.member_stats(None)
        est_t = estimate_words_touched(
            "tiled_fused", n, n // 2, n_words=stats.n_words, stats=stats
        )
        est_f = estimate_words_touched(
            "fused", n, n // 2, n_words=stats.n_words, stats=stats
        )
        predicted_win = est_t is not None and est_t < _TILED_ADVANTAGE * est_f
        rows.append({
            kind: param,
            "n": n,
            "n_tiles": n_tiles,
            "tiled_us": t_tiled * 1e6,
            "fused_us": t_fused * 1e6,
            "speedup": t_fused / t_tiled,
            "launches": info["launches"],
            "engine": info.get("engine"),
            "event_tiles": info.get("event_tiles", 0),
            "dirty_words_gathered": info["dirty_words_gathered"],
            "decode_words": info.get("decode_words", 0),
            "est_tiled_words": est_t,
            "est_fused_words": est_f,
            "predicted_win": predicted_win,
            "assert_win": predicted_win and not smoke and (
                kind == "clean_fraction" or param <= 1e-4 or not cpu
            ),
        })
    return rows


def batched_launch_collapse(smoke: bool = False) -> dict:
    """Launches for a batched multi-residual query (seed path: one launch
    per structurally distinct residual group; scan engine: <= 2)."""
    from repro.query import BitmapIndex, Interval, Threshold

    n, n_tiles = 8, 8 if smoke else 32
    bits = _clean_fraction_bits(n, n_tiles, 0.5, seed=3)
    idx = BitmapIndex.from_dense(jnp.asarray(bits))
    qs = [Threshold(2), Threshold(5), Interval(3, 6)]
    idx.execute_many(qs, backend="tiled_fused")
    info = idx.last_info
    import os

    os.environ["REPRO_TILED_ENGINE"] = "merge"
    try:
        idx.execute_many(qs, backend="tiled_fused")
    finally:
        del os.environ["REPRO_TILED_ENGINE"]
    return {
        "n_queries": len(qs),
        "residual_groups": info["residual_signatures"],
        "launches": info["launches"],
        "launches_per_group_path": idx.last_info["launches"],
    }


def run(smoke: bool = False):
    out = []
    rng = np.random.default_rng(0)
    shapes = [(16, 1 << 10)] if smoke else [(32, 1 << 16), (128, 1 << 16), (256, 1 << 14)]
    for n, nw in shapes:
        bm = jnp.asarray(rng.integers(0, 2**32, (n, nw), dtype=np.uint32))
        t = n // 2
        for alg in ("scancount", "ssum", "looped", "csvckt"):
            if alg == "looped" and n * t > 4000:
                continue
            dt = _time(lambda: threshold(bm, t, alg).block_until_ready())
            out.append((f"kernel_N{n}_{alg}_us", dt * 1e6, f"r={nw * 32}"))
        m = hbm_model(n, t, nw)
        out.append(
            (f"kernel_N{n}_hbm_ratio", m["ratio"],
             f"fused={m['fused_bytes'] / 2**20:.1f}MiB composed={m['composed_bytes'] / 2**20:.0f}MiB")
        )
        bw = pick_block_words(n, nw)
        vmem = 2 * n * bw * 4
        out.append((f"kernel_N{n}_block_words", bw, f"working_set={vmem / 2**20:.1f}MiB"))
    return out


def main(smoke: bool = False) -> dict:
    legacy = run(smoke=smoke)
    for name, val, extra in legacy:
        print(f"{name},{val:.2f},{extra}")
    crossover = tiled_crossover(smoke=smoke)
    batched = batched_launch_collapse(smoke=smoke)
    doc = {
        "backend": jax.default_backend(),
        "smoke": smoke,
        "crossover": crossover,
        "batched_multi_residual": batched,
        "legacy": [
            {"name": name, "value": val, "extra": extra}
            for name, val, extra in legacy
        ],
    }
    (REPO / "BENCH_kernel.json").write_text(json.dumps(doc, indent=2))
    for row in crossover:
        kind = "clean_fraction" if "clean_fraction" in row else "density"
        print(
            f"crossover_{kind}={row[kind]},tiled_us={row['tiled_us']:.0f},"
            f"fused_us={row['fused_us']:.0f},launches={row['launches']},"
            f"predicted_win={row['predicted_win']}"
        )
    print(
        f"batched_multi_residual,groups={batched['residual_groups']},"
        f"launches={batched['launches']} (per-group path: "
        f"{batched['launches_per_group_path']})"
    )
    # contract asserts: O(1) dispatch for the batched multi-residual query,
    # and measured wall-time wins wherever the words model predicts one on
    # a traffic-bound point (smoke shapes are dispatch-overhead-bound, so
    # only the launch contract is enforced there)
    assert batched["launches"] <= 2, batched
    for row in crossover:
        if row["predicted_win"]:
            assert row["launches"] <= 2, row
        if row["assert_win"]:
            assert row["tiled_us"] < row["fused_us"], row
    return doc


if __name__ == "__main__":
    import sys

    # --smoke: tiny shapes for CI, so fused-kernel perf regressions are at
    # least visible on every push without a long-running job
    main(smoke="--smoke" in sys.argv)
