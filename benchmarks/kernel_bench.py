"""Kernel-level comparison: fused Pallas threshold vs composed-jnp circuit
vs SCANCOUNT oracle.

On this CPU container the Pallas kernel runs in interpret mode (Python), so
wall-clock is meaningless for it; what we CAN measure and model:
  * wall time of the jnp circuit (XLA-fused on CPU) vs scancount,
  * the analytic HBM-traffic model for TPU: composed ops write every
    intermediate bit-plane (~(1 read + 1 write) x live plane per gate level)
    while the fused kernel streams N planes in and 1 out,
  * the VMEM working set implied by the chosen BlockSpec.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import circuits as C
from repro.core.threshold import threshold
from repro.kernels.threshold_ssum import pick_block_words


def _time(fn, reps=5):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def hbm_model(n: int, t: int, n_words: int) -> dict:
    """Bytes moved to/from HBM per threshold query (TPU model)."""
    gates = C.build_threshold_circuit(n, t, "ssum").gate_count()
    word_bytes = 4
    fused = (n + 1) * n_words * word_bytes  # stream in N planes, write 1
    # composed jnp: every gate reads 2 planes and writes 1 (upper bound; XLA
    # fusion recovers some, but bit-plane intermediates exceed cache at this r)
    composed = (3 * gates) * n_words * word_bytes
    return {"fused_bytes": fused, "composed_bytes": composed, "ratio": composed / fused}


def run(smoke: bool = False):
    out = []
    rng = np.random.default_rng(0)
    shapes = [(16, 1 << 10)] if smoke else [(32, 1 << 16), (128, 1 << 16), (256, 1 << 14)]
    for n, nw in shapes:
        bm = jnp.asarray(rng.integers(0, 2**32, (n, nw), dtype=np.uint32))
        t = n // 2
        for alg in ("scancount", "ssum", "looped", "csvckt"):
            if alg == "looped" and n * t > 4000:
                continue
            dt = _time(lambda: threshold(bm, t, alg).block_until_ready())
            out.append((f"kernel_N{n}_{alg}_us", dt * 1e6, f"r={nw * 32}"))
        m = hbm_model(n, t, nw)
        out.append(
            (f"kernel_N{n}_hbm_ratio", m["ratio"],
             f"fused={m['fused_bytes'] / 2**20:.1f}MiB composed={m['composed_bytes'] / 2**20:.0f}MiB")
        )
        bw = pick_block_words(n, nw)
        vmem = 2 * n * bw * 4
        out.append((f"kernel_N{n}_block_words", bw, f"working_set={vmem / 2**20:.1f}MiB"))
    return out


if __name__ == "__main__":
    import sys

    # --smoke: tiny shapes for CI, so fused-kernel perf regressions are at
    # least visible on every push without a long-running job
    for name, val, extra in run(smoke="--smoke" in sys.argv):
        print(f"{name},{val:.2f},{extra}")
