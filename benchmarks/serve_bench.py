"""Serving-throughput benchmark: the coalescing front-end vs per-query loops.

Synthetic multi-tenant workload: K logical clients concurrently submit
requests drawn (zipf-weighted) from a shared pool of hot queries -- the
abstract's "on sale in 2 to 10 stores" shape plus thresholds/composites
over store subsets -- against one :class:`repro.serve.QueryServer`.  The
headline number is queries/second, not single-query wall time:

  * **sequential baseline** -- the identical request stream executed one
    ``idx.execute`` at a time (what a naive per-request handler does; it
    still enjoys the compiled-circuit cache and plan memo);
  * **coalesced front-end** -- the same stream through ``QueryServer``:
    shape-bucketed micro-batches, semantic dedup, the version-keyed
    result cache, calibration feedback.

Writes ``BENCH_serve.json``: QPS per client count, p50/p95/p99 request
latency and queue wait (from the server's metrics-registry histograms),
batch-size histogram, cache-hit / dedup / shed rates, plan-memo counters,
measured calibration constants, and an oracle spot-check flag (every
distinct pool query served bit-identical to direct execution).  The smoke
config asserts the coalesced front-end clears >= 3x sequential QPS at
>= 8 clients and that p99 latency is finite and reported.
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import numpy as np

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_serve.json"

SMOKE = dict(n_cols=16, n_words=2048, clients=(1, 2, 4, 8), per_client=40,
             pool_size=12, repeats=1)
FULL = dict(n_cols=24, n_words=4096, clients=(1, 2, 4, 8, 16), per_client=200,
            pool_size=16, repeats=3)

MIN_SPEEDUP_AT_8 = 3.0


def _build_index(n_cols: int, n_words: int, seed: int = 0):
    from repro.stream import StreamingIndex

    rng = np.random.default_rng(seed)
    r = n_words * 32
    dens = rng.uniform(0.02, 0.4, n_cols)
    bits = rng.random((n_cols, r)) < dens[:, None]
    # clean territory so the tiled path is a real planner candidate
    bits[: n_cols // 3, : r // 2] = False
    names = [f"store{i}" for i in range(n_cols)]
    return StreamingIndex.from_dense(bits, names=names), names


def _query_pool(names, pool_size: int, seed: int = 1):
    from repro.query import And, AndNot, Col, Interval, Not, Threshold

    rng = np.random.default_rng(seed)
    pool = [Interval(2, 10)]  # the abstract's query, over every store
    while len(pool) < pool_size:
        k = int(rng.integers(3, min(8, len(names))))
        members = tuple(rng.choice(names, size=k, replace=False))
        t = int(rng.integers(1, k + 1))
        q = Threshold(t, over=members)
        style = len(pool) % 3
        if style == 1:
            q = And(q, Not(Col(str(rng.choice(names)))))
        elif style == 2:
            q = AndNot(Interval(1, max(1, k - 1), over=members), Col(str(rng.choice(names))))
        pool.append(q)
    return pool


def _request_streams(pool, clients: int, per_client: int, seed: int = 2):
    """Per-client request lists, zipf-weighted over the hot pool."""
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, len(pool) + 1)
    w /= w.sum()
    return [
        [pool[i] for i in rng.choice(len(pool), size=per_client, p=w)]
        for _ in range(clients)
    ]


def _sequential_qps(stream_idx, requests, repeats: int) -> float:
    import jax

    idx = stream_idx.index()
    best = 0.0
    for _ in range(repeats):
        t0 = time.perf_counter()
        for q in requests:
            jax.block_until_ready(idx.execute(q))
        wall = time.perf_counter() - t0
        best = max(best, len(requests) / wall)
    return best


def _coalesced_qps(stream_idx, streams, repeats: int, window: float):
    from repro.serve import QueryServer

    best = None
    for _ in range(repeats):
        server = QueryServer(stream_idx, window=window, max_pending=4096)
        server.start()
        results: list = [None] * len(streams)

        def client(ci: int) -> None:
            futs = [server.submit(q) for q in streams[ci]]
            results[ci] = [f.result(60) for f in futs]

        t0 = time.perf_counter()
        threads = [
            threading.Thread(target=client, args=(ci,)) for ci in range(len(streams))
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        server.stop()
        n = sum(len(s) for s in streams)
        qps = n / wall
        if best is None or qps > best[0]:
            best = (qps, server.info())
    return best


def _oracle_check(stream_idx, pool) -> bool:
    """Every distinct pool query served through the front-end must be
    bit-identical to direct execution."""
    from repro.serve import QueryServer

    idx = stream_idx.index()
    server = QueryServer(stream_idx, window=0)
    futs = [server.submit(q) for q in pool]
    while server.pump():
        pass
    for q, f in zip(pool, futs):
        got = np.asarray(f.result(0))
        ref = np.asarray(idx.execute(q))
        if not np.array_equal(got, ref):
            return False
    return True


def run(smoke: bool = True):
    import jax

    from repro.core.calibration import measure_calibration, set_calibration
    from repro.query import clear_compiled_cache, plan_memo_info

    cfg = SMOKE if smoke else FULL
    stream_idx, names = _build_index(cfg["n_cols"], cfg["n_words"])
    pool = _query_pool(names, cfg["pool_size"])

    # measured words->us constants steer every plan below and land in the
    # artifact; the 'repeats' keep the pass cheap on CPU
    calib = measure_calibration(repeats=2, n_words=min(cfg["n_words"], 1024))
    set_calibration(calib)

    # absorb compilation for both paths: each distinct query runs once
    idx = stream_idx.index()
    for q in pool:
        jax.block_until_ready(idx.execute(q))

    data = {
        "device": jax.default_backend(),
        "config": {k: (list(v) if isinstance(v, tuple) else v) for k, v in cfg.items()},
        "calibration": calib.to_obj(),
        "sweep": [],
    }
    rows = []

    oracle_ok = _oracle_check(stream_idx, pool)
    data["oracle_bit_identical"] = bool(oracle_ok)
    assert oracle_ok, "served results diverged from direct execution"

    seq_qps = None
    speedup_at_8 = None
    for clients in cfg["clients"]:
        streams = _request_streams(pool, clients, cfg["per_client"])
        flat = [q for s in streams for q in s]
        if seq_qps is None:  # request mix is identical per client count
            seq_qps = _sequential_qps(stream_idx, flat, cfg["repeats"])
            data["sequential_qps"] = seq_qps
            rows.append(("serve_sequential_qps", seq_qps, "per-query execute loop"))
        qps, info = _coalesced_qps(
            stream_idx, streams, cfg["repeats"], window=0.001
        )
        served = max(1, info["served"])
        point = {
            "clients": clients,
            "offered": len(flat),
            "qps": qps,
            "speedup_vs_sequential": qps / seq_qps,
            "cache_hit_rate": info["cache_hits"] / served,
            "dedup_rate": info["dedup_hits"] / served,
            "shed": info["shed"],
            "executed": info["executed"],
            "batches": info["batches"],
            "batch_size_hist": info["batch_size_hist"],
            "plan_memo": info["plan_memo"],
            # request latency + queue wait from the server's metrics
            # registry histograms (exact-merge log-bucketed percentiles)
            "latency_s": info["latency"],
            "queue_wait_s": info["queue_wait"],
        }
        data["sweep"].append(point)
        rows.append(
            (
                f"serve_qps_c{clients}",
                qps,
                f"{qps / seq_qps:.1f}x seq; cache {point['cache_hit_rate']:.0%} "
                f"dedup {point['dedup_rate']:.0%} exec {info['executed']} "
                f"p99 {info['latency']['p99_s'] * 1e3:.2f}ms",
            )
        )
        rows.append(
            (
                f"serve_p99_ms_c{clients}",
                info["latency"]["p99_s"] * 1e3,
                f"p50 {info['latency']['p50_s'] * 1e3:.2f}ms queue-wait p99 "
                f"{info['queue_wait']['p99_s'] * 1e3:.2f}ms",
            )
        )
        if clients >= 8 and speedup_at_8 is None:
            speedup_at_8 = qps / seq_qps

    data["plan_memo"] = plan_memo_info()
    OUT_PATH.write_text(json.dumps(data, indent=2, sort_keys=True))
    rows.append(("bench_serve_json", 1, str(OUT_PATH)))

    if smoke and speedup_at_8 is not None:
        assert speedup_at_8 >= MIN_SPEEDUP_AT_8, (
            f"coalesced front-end only {speedup_at_8:.2f}x sequential at >=8 "
            f"clients (need >= {MIN_SPEEDUP_AT_8}x)"
        )
    if smoke:
        import math

        for point in data["sweep"]:
            p99 = point["latency_s"]["p99_s"]
            assert math.isfinite(p99) and p99 > 0, (
                f"p99 latency not finite at {point['clients']} clients: {p99}"
            )
            assert point["latency_s"]["count"] > 0, "latency histogram empty"
    set_calibration(None)
    clear_compiled_cache()
    return rows


if __name__ == "__main__":
    import sys

    smoke = "--smoke" in sys.argv
    for name, val, extra in run(smoke=smoke):
        print(f"{name},{val if isinstance(val, int) else round(float(val), 3)},{extra}")
