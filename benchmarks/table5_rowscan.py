"""Table 5: bitmap-index SCANCOUNT vs a no-index row scan.

The paper's point: answering a T-occurrence query from a bitmap index beats
scanning the base table ~4x (random-attribute queries) and still wins on
similarity queries.  We reproduce the *structure*: a row-store table of D
attributes vs its unary bitmap index, timed on the same query set.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitmaps import pack
from repro.core.threshold import threshold


def build_table(rows=10_000, attrs=42, values=8, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, values, (rows, attrs), dtype=np.int32)


def row_scan(table, query, t):
    """Algorithm 1: per-row counter over attribute predicates."""
    counts = (table == np.asarray(query)[None, :]).sum(axis=1)
    return np.nonzero(counts >= t)[0]


def bitmap_index(table, values):
    rows, attrs = table.shape
    bitmaps = []
    for a in range(attrs):
        for v in range(values):
            bitmaps.append(table[:, a] == v)
    packed = pack(jnp.asarray(np.stack(bitmaps)))
    return packed


def run(reps=5):
    rows, attrs, values = 10_000, 42, 8
    table = build_table(rows, attrs, values)
    index = bitmap_index(table, values)
    rng = np.random.default_rng(1)
    results = []
    for trial in range(10):
        query = rng.integers(0, values, attrs)
        t = int(rng.integers(2, attrs - 1))
        sel = jnp.asarray([a * values + int(v) for a, v in enumerate(query)])
        chosen = jnp.take(index, sel, axis=0)
        # warm
        expect = row_scan(table, query, t)
        got = np.asarray(threshold(chosen, t, "scancount"))
        t0 = time.perf_counter()
        for _ in range(reps):
            row_scan(table, query, t)
        t_row = (time.perf_counter() - t0) / reps
        fn = jax.jit(lambda b: threshold(b, t, "scancount"))
        fn(chosen).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(reps):
            fn(chosen).block_until_ready()
        t_idx = (time.perf_counter() - t0) / reps
        results.append((t_row, t_idx))
    row = np.mean([r[0] for r in results])
    idx = np.mean([r[1] for r in results])
    return [
        ("table5_rowscan_us", row * 1e6, ""),
        ("table5_bitmap_scancount_us", idx * 1e6, f"speedup={row / idx:.1f}x"),
    ]


if __name__ == "__main__":
    for name, val, extra in run():
        print(f"{name},{val:.1f},{extra}")
