"""Figures 3/4: scaling of the threshold algorithms with N and with T.

Fig 3: time vs N at T = N/2 (normalised to N=32, as in the paper).
Fig 4: time vs T at N = 64 on one bitmap set.
Times are wall-clock over jitted calls on the synthetic 5.3 datasets.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.threshold import threshold
from repro.data.paper_datasets import synthetic_dataset

ALGOS = ("scancount", "looped", "ssum", "treeadd", "csvckt", "fused")


def _time(fn, reps=3):
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run():
    out = []
    packed, r, _ = synthetic_dataset("clustered", "dense", n_bitmaps=128, card=4000, seed=1111)
    full = jnp.asarray(packed)
    # Fig 3: N scaling at T=N/2
    base: dict = {}
    for n in (8, 16, 32, 64, 128):
        bm = full[:n]
        for alg in ALGOS:
            t = n // 2
            if alg == "looped" and n * t > 4000:
                continue  # LOOPED is an O(NT)-op small-T algorithm (paper 4.5)
            dt = _time(lambda: threshold(bm, t, alg).block_until_ready())
            if n == 32:
                base[alg] = dt
            out.append((f"fig3_{alg}_N{n}_us", dt * 1e6, f"T={t}"))
    # Fig 4: T scaling at N=64
    bm = full[:64]
    for t in (2, 3, 8, 16, 32, 48, 61, 63):
        for alg in ALGOS:
            if alg == "looped" and 64 * t > 4000:
                continue
            dt = _time(lambda: threshold(bm, t, alg).block_until_ready())
            out.append((f"fig4_{alg}_T{t}_us", dt * 1e6, "N=64"))
    return out


if __name__ == "__main__":
    for name, val, extra in run():
        print(f"{name},{val:.1f},{extra}")
