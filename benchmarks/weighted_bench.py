"""Weighted thresholds: the paper's replication (2.3) vs our binary
decomposition -- gate counts and equivalence across weight magnitudes."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.bitmaps import pack
from repro.core.weighted import (
    decomposed_gate_cost,
    replication_gate_cost,
    weighted_threshold_decomposed,
)


def run():
    out = []
    rng = np.random.default_rng(0)
    for n, wmax in [(16, 7), (16, 100), (32, 1000)]:
        weights = [int(x) for x in rng.integers(1, wmax + 1, n)]
        t = sum(weights) // 2
        rep = replication_gate_cost(weights, t)
        dec = decomposed_gate_cost(weights, t)
        out.append(
            (f"weighted_N{n}_wmax{wmax}_replication_gates", rep, "paper 2.3 approach")
        )
        out.append(
            (f"weighted_N{n}_wmax{wmax}_decomposed_gates", dec,
             f"ours; {rep / dec:.1f}x smaller")
        )
        bits = rng.random((n, 500)) < 0.3
        got = weighted_threshold_decomposed(pack(jnp.asarray(bits)), tuple(weights), t)
        expect = (bits * np.array(weights)[:, None]).sum(0) >= t
        from repro.core.bitmaps import unpack

        assert (np.asarray(unpack(got, 500)) == expect).all()
    return out


if __name__ == "__main__":
    for name, val, extra in run():
        print(f"{name},{val},{extra}")
