"""Paper 5.4/5.8: SMALL-COMPETITIONS heatmap + Appendix C suboptimality.

Runs the paper's competition protocol: for each (N, T) pair of the
SMALL-COMPETITIONS schedule, race the algorithms on similarity queries and
rank them.  Produces (a) per-algorithm win / within-50% / terrible
percentages (the paper's heat-map aggregates) and (b) mean suboptimality
(Appendix C).  CPU wall-clock; the *relative* conclusions are what the
paper reports (RBMRG/adders robust, LOOPED wins small T, pruning wins
T ~ N on sparse data).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import listalgos as LA
from repro.core.threshold import threshold
from repro.storage import TileStore, rbmrg_block_threshold
from repro.data.paper_datasets import similarity_query, synthetic_dataset


def small_competitions():
    """The paper's (N, T) schedule: doubling N; T' and N+2-T' ladders."""
    pairs = []
    for n in (4, 8, 16, 32):
        ts = set()
        tp = 3
        while tp <= n // 2 + 1:
            ts.add(tp)
            ts.add(n + 2 - tp)
            tp = (3 * tp) // 2
        for t in sorted(x for x in ts if 2 <= x <= n - 1):
            pairs.append((n, t))
    return pairs


def _time(fn, reps=2):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run():
    packed, r, lists = synthetic_dataset("clustered", "dense", n_bitmaps=64,
                                         card=3000, seed=1111)
    stats_cache = {}
    wins: dict[str, int] = {}
    ok50: dict[str, int] = {}
    terrible: dict[str, int] = {}
    subopt: dict[str, list] = {}
    n_comp = 0
    for n, t in small_competitions():
        sel, rid = similarity_query(lists, n, seed=n * 131 + t)
        bm = jnp.asarray(packed[sel])
        sel_lists = [lists[i] for i in sel]
        key = tuple(sel)
        if key not in stats_cache:
            stats_cache[key] = TileStore.from_packed(bm).block_stats()
        stats = stats_cache[key]
        times = {}
        for alg in ("scancount", "ssum", "csvckt", "fused"):
            times[alg] = _time(lambda a=alg: threshold(bm, t, a).block_until_ready())
        if n * t <= 4000:
            times["looped"] = _time(lambda: threshold(bm, t, "looped").block_until_ready())
        times["rbmrg_block"] = _time(lambda: rbmrg_block_threshold(bm, t, stats=stats))
        times["dsk"] = _time(lambda: LA.dsk(sel_lists, t, r))
        times["w2cti"] = _time(lambda: LA.w2cti(sel_lists, t, r))
        best = min(times.values())
        n_comp += 1
        for alg, dt in times.items():
            wins[alg] = wins.get(alg, 0) + (dt == best)
            ok50[alg] = ok50.get(alg, 0) + (dt <= 1.5 * best)
            terrible[alg] = terrible.get(alg, 0) + (dt >= 10 * best)
            subopt.setdefault(alg, []).append(dt / best - 1.0)
    out = []
    for alg in sorted(subopt, key=lambda a: float(np.mean(subopt[a]))):
        out.append(
            (
                f"heatmap_{alg}_mean_subopt",
                float(np.mean(subopt[alg])),
                f"wins={100 * wins[alg] / n_comp:.0f}% within50={100 * ok50[alg] / n_comp:.0f}% "
                f"terrible={100 * terrible[alg] / n_comp:.0f}%",
            )
        )
    out.append(("heatmap_competitions", n_comp, "SMALL-COMPETITIONS pairs"))
    return out


if __name__ == "__main__":
    for name, val, extra in run():
        print(f"{name},{val},{extra}")
