"""Similarity-search + windowed-analytics benchmark (repro.search).

Two sweeps, one artifact (``BENCH_search.json``):

* **candidate generation** -- the Sarawagi-Kirpal T-occurrence query for
  edit-distance screening, raced head-to-head per query: the bitmap
  threshold circuit (planner path over q-gram columns) vs the paper's
  integer-list competitors (``core.listalgos`` MergeOpt / DivideSkip /
  WHEAP) merging the same posting lists at the same T.  Both sides
  produce identical candidate ids (asserted).  The headline number is
  the speedup over DivideSkip; the smoke run asserts the bitmap path
  clears >= 1x DivideSkip at >= 1 sweep point.  Adaptive ``topk`` wall
  time and relaxation/verification counts ride along.

* **windowed analytics** -- an event stream with a materialized window
  count under append + expiry batches.  Every refresh is checked against
  the touched-tiles words bound (``words_touched <= tiles_refreshed *
  tile_words * (|support| + 1)``) -- the no-rebuild evidence: refresh
  work scales with the mutation batch, never the universe.

``--smoke`` runs small shapes for CI with the assertions on.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_search.json"

SMOKE = dict(corpus=4000, name_len=(6, 14), queries=6, k=2, repeats=3,
             window_batches=8, batch_events=400, n_series=6)
FULL = dict(corpus=20000, name_len=(6, 16), queries=16, k=2, repeats=5,
            window_batches=24, batch_events=2000, n_series=12)

ALPHA = "abcdefghijklmnop"


def _corpus(n, lo, hi, seed=0):
    rng = np.random.default_rng(seed)
    return [
        "".join(ALPHA[i] for i in rng.integers(0, len(ALPHA), rng.integers(lo, hi)))
        for _ in range(n)
    ]


def _median_time(fn, repeats):
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def candidate_race(cfg) -> tuple[list, dict]:
    from repro.core import listalgos as LA
    from repro.search import build_qgram_index

    corpus = _corpus(cfg["corpus"], *cfg["name_len"])
    idx = build_qgram_index(corpus, q=2)
    rng = np.random.default_rng(3)
    # queries are perturbed corpus members: realistic selectivity, known hits
    queries = []
    for qi in rng.choice(cfg["corpus"], size=cfg["queries"], replace=False):
        s = corpus[int(qi)]
        pos = int(rng.integers(0, len(s)))
        queries.append(s[:pos] + ALPHA[int(rng.integers(0, len(ALPHA)))] + s[pos + 1:])

    competitors = {"dsk": LA.dsk, "mgopt": LA.mgopt, "wheap": LA.wheap}
    points, best_speedup = [], 0.0
    for s in queries:
        cand = idx.candidates(s, cfg["k"])  # warm-up: compiles the circuit
        lists = idx.posting_lists(s)
        t_bitmap = _median_time(lambda: idx.candidates(s, cfg["k"]), cfg["repeats"])
        point = {
            "query": s,
            "t": cand.t,
            "n_lists": len(lists),
            "list_elems": int(sum(l.size for l in lists)),
            "n_candidates": len(cand),
            "bitmap_s": t_bitmap,
            "lists_s": {},
        }
        if cand.t >= 1:  # the list merges have no vacuous mode
            for name, algo in competitors.items():
                got = algo(lists, cand.t, idx.r)
                assert np.array_equal(np.asarray(got), cand.ids), (
                    f"{name} disagrees with the bitmap candidates on {s!r}"
                )
                point["lists_s"][name] = _median_time(
                    lambda a=algo: a(lists, cand.t, idx.r), cfg["repeats"]
                )
            point["speedup_vs_dsk"] = point["lists_s"]["dsk"] / t_bitmap
            best_speedup = max(best_speedup, point["speedup_vs_dsk"])
        points.append(point)

    # k=1: the planted perturbation is the nearest neighbour, so the loop
    # stops after the first relaxation band instead of widening to vacuous
    tk = idx.topk(queries[0], 1)  # warm
    t_topk = _median_time(lambda: idx.topk(queries[0], 1), cfg["repeats"])
    topk_info = {
        "k": 1,
        "wall_s": t_topk,
        "relaxations": tk.relaxations,
        "verified": tk.verified,
        "corpus": idx.r,
        "verified_fraction": tk.verified / idx.r,
    }
    rows = [
        ("search_best_speedup_vs_dsk", best_speedup,
         f"{len(points)} queries corpus={idx.r}"),
        ("search_topk_ms", t_topk * 1e3,
         f"verified {tk.verified}/{idx.r} rows in {tk.relaxations} bands"),
    ]
    return rows, {"points": points, "topk": topk_info,
                  "best_speedup_vs_dsk": best_speedup}


def window_sweep(cfg) -> tuple[list, dict]:
    from repro.query.expr import Col, Threshold
    from repro.search import WindowedStream, WindowRetentionPolicy

    series = [f"s{i}" for i in range(cfg["n_series"])]
    ws = WindowedStream(
        series, window=30.0 * cfg["batch_events"] / 100.0, tile_words=8,
        policy=WindowRetentionPolicy(min_dead_rows=1 << 30),  # no retire: pure bound test
    )
    ws.watch("hot", Threshold(2, over=[Col(s) for s in series]))
    rng = np.random.default_rng(9)
    sup = 1 + cfg["n_series"]  # __live__ + every series the watch reads
    tw = ws.stream.tile_words
    refreshes, t = [], 0.0
    worst_ratio = 0.0
    for _ in range(cfg["window_batches"]):
        batch = []
        for _ in range(cfg["batch_events"]):
            t += float(rng.uniform(0.0, 0.2))
            cols = rng.choice(series, size=int(rng.integers(1, 4)), replace=False)
            batch.append((t, list(cols)))
        ws.append(batch)
        info = ws.refresh_info("hot")
        bound = info["tiles_refreshed"] * tw * (sup + 1)
        assert info["words_touched"] <= bound, (
            f"refresh touched {info['words_touched']} words, bound {bound}"
        )
        universe_words = ws.stream.index().n_words
        worst_ratio = max(worst_ratio, info["words_touched"] / max(bound, 1))
        refreshes.append({**info, "bound": bound, "universe_words": universe_words,
                          "live": ws.live_events, "total_rows": ws.total_rows,
                          "count": ws.count("hot")})
    # the no-rebuild claim: late refreshes touch far fewer words than the
    # (ever-growing) universe holds per support column
    tail = refreshes[-1]
    assert tail["words_touched"] < tail["universe_words"] * sup, "refresh ~ rebuild?"
    rows = [
        ("window_events", cfg["window_batches"] * cfg["batch_events"],
         f"live {ws.live_events} dead {ws.dead_rows} count {ws.count('hot')}"),
        ("window_words_touched_vs_bound", worst_ratio,
         f"tail refresh {tail['words_touched']}w vs universe "
         f"{tail['universe_words']}w x {sup} support cols"),
    ]
    return rows, {"refreshes": refreshes, "series": len(series),
                  "tile_words": tw, "support": sup}


def run(smoke: bool = False) -> list:
    cfg = SMOKE if smoke else FULL
    rows, data = [], {"smoke": smoke, "config": cfg}
    r1, d1 = candidate_race(cfg)
    rows += r1
    data["candidates"] = d1
    r2, d2 = window_sweep(cfg)
    rows += r2
    data["window"] = d2
    OUT_PATH.write_text(json.dumps(data, indent=2, sort_keys=True))
    rows.append(("bench_search_json", 1, str(OUT_PATH)))
    if smoke:
        assert d1["best_speedup_vs_dsk"] >= 1.0, (
            f"bitmap candidate generation never reached DivideSkip: best "
            f"{d1['best_speedup_vs_dsk']:.2f}x"
        )
    return rows


if __name__ == "__main__":
    import sys

    smoke = "--smoke" in sys.argv
    for name, val, extra in run(smoke=smoke):
        print(f"{name},{val if isinstance(val, int) else round(float(val), 3)},{extra}")
