"""Observability overhead + export-surface benchmark (repro.obs).

Measures what the unified observability layer costs on the query hot
path and exercises every export surface:

  * **enabled overhead** -- the same steady-state query stream (plan memo
    and compiled-circuit cache hot) timed with metrics + tracing OFF vs
    ON; the smoke config asserts the enabled overhead stays under
    ``MAX_ENABLED_OVERHEAD_PCT``;
  * **disabled cost** -- the instrumented hot path with observability off
    pays one attribute load + branch per site; a micro-bench times that
    disabled site cost and reports the implied per-query overhead
    (asserted under ``MAX_DISABLED_OVERHEAD_PCT``);
  * **drift accounting** -- after the enabled run the calibration-drift
    sample count must be nonzero (every traced execute records one
    predicted-vs-measured observation);
  * **export lint** -- the Prometheus text exposition must pass the
    pure-Python scrape lint (``repro.obs.lint_prometheus``), and the
    JSONL metrics + last span tree land in ``BENCH_obs_trace.jsonl``
    (the CI artifact).

Writes ``BENCH_obs.json`` with the walls, overhead percentages, drift
counts and lint status.
"""
from __future__ import annotations

import json
import math
import time
from pathlib import Path

import numpy as np

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_obs.json"
TRACE_PATH = Path(__file__).resolve().parent.parent / "BENCH_obs_trace.jsonl"

SMOKE = dict(n_cols=12, n_words=4096, n_queries=100, repeats=7)
FULL = dict(n_cols=24, n_words=8192, n_queries=400, repeats=7)

MAX_ENABLED_OVERHEAD_PCT = 5.0
MAX_DISABLED_OVERHEAD_PCT = 2.0
#: instrumentation sites one traced execute touches (spans + spot
#: counters + drift observes); deliberately over-counted for the implied
#: disabled-cost bound
DISABLED_SITES_PER_QUERY = 24


def _build_index(n_cols: int, n_words: int, seed: int = 0):
    from repro.query import BitmapIndex

    rng = np.random.default_rng(seed)
    r = n_words * 32
    dens = rng.uniform(0.02, 0.4, n_cols)
    bits = rng.random((n_cols, r)) < dens[:, None]
    bits[: n_cols // 3, : r // 2] = False  # clean territory for tiling
    names = [f"store{i}" for i in range(n_cols)]
    return BitmapIndex.from_dense(bits, names=names), names


def _query_pool(names, n_queries: int, seed: int = 1):
    from repro.query import And, Col, Interval, Not, Threshold

    rng = np.random.default_rng(seed)
    pool = [Interval(2, 10)]  # the abstract's 2-to-10-stores query
    while len(pool) < n_queries:
        k = int(rng.integers(3, min(8, len(names))))
        members = tuple(rng.choice(names, size=k, replace=False))
        t = int(rng.integers(1, k + 1))
        q = Threshold(t, over=members)
        if len(pool) % 3 == 1:
            q = And(q, Not(Col(str(rng.choice(names)))))
        pool.append(q)
    return pool


def _one_pass(idx, pool) -> float:
    import jax

    t0 = time.perf_counter()
    for q in pool:
        jax.block_until_ready(idx.execute(q))
    return time.perf_counter() - t0


def _time_off_on(idx, pool, repeats: int) -> tuple[float, float, float]:
    """(off wall, on wall, overhead %) with obs OFF vs ON, per query.

    Each query is timed individually with the two modes interleaved
    back-to-back (off execute, on execute), ``repeats`` times; the
    per-mode wall is the sum over the pool of each query's MEDIAN time.
    Pass-level timing is not robust on shared boxes: scheduler/thermal
    bursts span whole passes and exceed the instrumentation cost being
    measured, while back-to-back pairing hits both modes with the same
    burst and the per-query median discards the outliers entirely."""
    import statistics

    import jax

    import repro.obs as obs

    off_t: list[list[float]] = [[] for _ in pool]
    on_t: list[list[float]] = [[] for _ in pool]
    for _ in range(repeats):
        for qi, q in enumerate(pool):
            obs.disable()
            t0 = time.perf_counter()
            jax.block_until_ready(idx.execute(q))
            off_t[qi].append(time.perf_counter() - t0)
            obs.enable(slow_query_threshold_s=0.050)
            t0 = time.perf_counter()
            jax.block_until_ready(idx.execute(q))
            on_t[qi].append(time.perf_counter() - t0)
    obs.disable()
    wall_off = sum(statistics.median(t) for t in off_t)
    wall_on = sum(statistics.median(t) for t in on_t)
    return wall_off, wall_on, 100.0 * (wall_on - wall_off) / wall_off


def _disabled_site_cost() -> float:
    """Seconds per disabled instrumentation site (counter inc + span)."""
    import repro.obs as obs
    from repro.obs import trace

    assert not obs.enabled()
    c = obs.counter("repro_obs_bench_disabled_probe_total")
    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        c.inc(1)
        trace.span("probe")
    return (time.perf_counter() - t0) / (2 * n)


def run(smoke: bool = True):
    import jax

    import repro.obs as obs
    from repro.query import clear_compiled_cache

    cfg = SMOKE if smoke else FULL
    idx, names = _build_index(cfg["n_cols"], cfg["n_words"])
    pool = _query_pool(names, cfg["n_queries"])

    # warm everything BOTH modes will touch: compiles, plan memo, and the
    # lazy imports the first instrumented call performs
    obs.enable()
    for q in pool:
        jax.block_until_ready(idx.execute(q))
    obs.reset()

    wall_off, wall_on, enabled_overhead_pct = _time_off_on(
        idx, pool, cfg["repeats"]
    )

    site_cost = _disabled_site_cost()
    per_query_s = wall_off / len(pool)
    implied_disabled_pct = (
        100.0 * DISABLED_SITES_PER_QUERY * site_cost / per_query_s
    )

    # the drift / trace / export surfaces read the LAST enabled pass
    obs.enable(slow_query_threshold_s=0.050)
    obs.reset()
    for q in pool:
        jax.block_until_ready(idx.execute(q))

    drift = obs.drift_samples()
    last = obs.last_trace()
    prom = obs.export_prometheus()
    problems = obs.lint_prometheus(prom)

    lines = obs.export_jsonl().rstrip("\n").split("\n")
    lines.append(json.dumps(
        {"last_trace": None if last is None else last.to_dict()},
        default=str,
    ))
    TRACE_PATH.write_text("\n".join(lines) + "\n")

    dump = obs.dump()
    data = {
        "device": jax.default_backend(),
        "config": dict(cfg),
        "wall_off_s": wall_off,
        "wall_on_s": wall_on,
        "enabled_overhead_pct": enabled_overhead_pct,
        "disabled_site_cost_ns": site_cost * 1e9,
        "implied_disabled_overhead_pct": implied_disabled_pct,
        "drift_samples": drift,
        "drift": dump["drift"],
        "prometheus_lint_problems": problems,
        "prometheus_bytes": len(prom),
        "trace_artifact": str(TRACE_PATH),
    }
    OUT_PATH.write_text(json.dumps(data, indent=2, sort_keys=True))

    rows = [
        ("obs_wall_off_ms", wall_off * 1e3,
         f"{len(pool)} queries (per-query medians), tracing off"),
        ("obs_wall_on_ms", wall_on * 1e3, "same stream, metrics+tracing on"),
        ("obs_enabled_overhead_pct", enabled_overhead_pct,
         f"bound {MAX_ENABLED_OVERHEAD_PCT}%"),
        ("obs_disabled_site_ns", site_cost * 1e9,
         f"implied {implied_disabled_pct:.3f}%/query (bound "
         f"{MAX_DISABLED_OVERHEAD_PCT}%)"),
        ("obs_drift_samples", int(drift), "predicted-vs-measured observations"),
        ("obs_prom_lint_problems", len(problems),
         "; ".join(problems) if problems else "scrape-clean"),
        ("bench_obs_json", 1, str(OUT_PATH)),
        ("bench_obs_trace_jsonl", 1, str(TRACE_PATH)),
    ]

    assert drift >= len(pool), (
        f"drift accounting broke: {drift} samples after {len(pool)} queries"
    )
    assert not problems, f"Prometheus lint problems: {problems}"
    assert last is not None and last.find("plan") is not None, (
        "traced run left no span tree with a plan span"
    )
    if smoke:
        assert enabled_overhead_pct < MAX_ENABLED_OVERHEAD_PCT, (
            f"metrics+tracing cost {enabled_overhead_pct:.2f}% "
            f"(bound {MAX_ENABLED_OVERHEAD_PCT}%)"
        )
        assert implied_disabled_pct < MAX_DISABLED_OVERHEAD_PCT, (
            f"disabled instrumentation implies {implied_disabled_pct:.3f}% "
            f"(bound {MAX_DISABLED_OVERHEAD_PCT}%)"
        )

    obs.disable()
    obs.reset()
    clear_compiled_cache()
    return rows


if __name__ == "__main__":
    import sys

    smoke = "--smoke" in sys.argv
    for name, val, extra in run(smoke=smoke):
        print(f"{name},{val if isinstance(val, int) else round(float(val), 3)},{extra}")
