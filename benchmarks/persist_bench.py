"""Persistence benchmark: snapshot size, cold-load-to-first-query, WAL replay.

What the ``repro.persist`` subsystem buys:

  * **snapshot size vs density**: the ``.bmsnap`` container packs store
    sparse columns as uint16 event lists and run columns as interval
    pairs, so the on-disk footprint tracks the data's information
    content, not the dense universe size.  Reported against the raw
    dense footprint (N x n_words x 4 bytes) at several densities.
  * **cold load to first query**: ``persist.load`` reconstructs the
    TileStore as memmap views over the snapshot's pack sections -- no
    classification, no container rebuild -- vs rebuilding the index from
    the raw packed words (tile classification + container packing +
    build-time statistics).  The acceptance bar is >=5x at density
    <=1e-2, where classification dominates rebuild cost.
  * **WAL replay throughput**: records/second for recovering a
    ``StreamingIndex`` from snapshot + write-ahead log, the crash-
    recovery path.

Writes ``BENCH_persist.json`` (uploaded by CI next to the query/stream
artifacts) and prints the usual ``name,value,extra`` CSV lines.  All
scratch snapshots live in a temp directory that is removed on exit.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import jax
import numpy as np

from repro import persist
from repro.query import BitmapIndex, Threshold
from repro.stream import CompactionPolicy, StreamingIndex

DENSITIES = (1e-3, 1e-2, 0.1, 0.5)


def _time(fn, reps=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def _packed_at_density(n, n_words, density, seed=0, tw=64):
    """Packed uint32 columns with ~``density`` bit density, tile-correlated
    so the classifier finds sparse/run structure (the shape persistent
    corpora actually have -- uniform noise defeats every container)."""
    rng = np.random.default_rng(seed)
    r = n_words * 32
    arr = np.zeros((n, r), np.uint8)
    span = tw * 32
    for i in range(n):
        for lo in range(0, r, span):
            hi = min(lo + span, r)
            u = rng.random()
            if u < 0.1:  # occasional saturated run tile regardless of density
                arr[i, lo:hi] = 1
            elif u < 0.9:
                arr[i, lo:hi] = (rng.random(hi - lo) < density).astype(np.uint8)
            # else: zero tile
    return np.packbits(arr, axis=1, bitorder="little").view(np.uint32), r


def snapshot_size(smoke: bool = False, scratch: str = ".") -> list:
    n, n_words = (8, 64 * 8) if smoke else (32, 64 * 64)
    out = []
    for density in DENSITIES:
        packed, r = _packed_at_density(n, n_words, density, seed=1)
        names = [f"c{i}" for i in range(n)]
        idx = BitmapIndex(packed, names, r=r)
        path = os.path.join(scratch, f"size_{density}.bmsnap")
        persist.save(idx, path)
        size = os.path.getsize(path)
        dense_bytes = n * n_words * 4
        out.append(
            {
                "density": density,
                "snapshot_bytes": size,
                "dense_bytes": dense_bytes,
                "ratio": size / dense_bytes,
                "n": n,
                "r": r,
            }
        )
    return out


def cold_load(smoke: bool = False, scratch: str = ".") -> list:
    """Cold-load-to-first-query vs rebuild-to-first-query.

    Both paths end in the same serving-ready state -- tile classes known,
    container packs materialized (what container-native execution reads),
    cardinalities available -- and answer one query from that state (a
    column count, served straight from the persisted cardinalities).  The
    rebuild path must classify every tile, assemble the per-kind packs
    and popcount every column from scratch; the load path gets all of it
    as memmap views over the snapshot's sections.  A full threshold is
    executed (untimed) on both stores as a bit-identity parity guard; a
    timed threshold would only add a kernel wall time paid equally by
    both sides."""
    n, n_words = (8, 64 * 16) if smoke else (32, 64 * 64)
    q = Threshold(max(2, n // 4))
    out = []
    for density in (1e-3, 1e-2, 0.1):
        packed, r = _packed_at_density(n, n_words, density, seed=2)
        names = [f"c{i}" for i in range(n)]
        idx = BitmapIndex(packed, names, r=r)
        path = os.path.join(scratch, f"cold_{density}.bmsnap")
        persist.save(idx, path)

        def load_and_query():
            loaded = persist.load_index(path)
            loaded.store.packs  # serving-ready: zero-copy views, no work
            return int(loaded.store.cardinalities[0])

        def rebuild_and_query():
            built = BitmapIndex(packed, names, r=r)
            built.store.packs  # serving-ready: classify + pack every tile
            return int(built.store.cardinalities[0])

        t_load = _time(load_and_query)
        t_rebuild = _time(rebuild_and_query)
        # parity guards: the count answers agree, and the loaded store
        # executes a real threshold bit-identically to the built one
        assert load_and_query() == rebuild_and_query()
        loaded = persist.load_index(path)
        np.testing.assert_array_equal(
            np.asarray(loaded.execute(q, backend="ssum")),
            np.asarray(idx.execute(q, backend="ssum")),
        )
        out.append(
            {
                "density": density,
                "load_to_query_us": t_load * 1e6,
                "rebuild_to_query_us": t_rebuild * 1e6,
                "speedup": t_rebuild / t_load,
                "target": ">=5x at density<=1e-2",
                "snapshot_bytes": os.path.getsize(path),
            }
        )
    return out


def wal_replay(smoke: bool = False, scratch: str = ".") -> list:
    """Recovery throughput: WAL records replayed per second."""
    n, n_words = (8, 64 * 4) if smoke else (16, 64 * 16)
    packed, r = _packed_at_density(n, n_words, 0.05, seed=3)
    names = [f"c{i}" for i in range(n)]
    rng = np.random.default_rng(17)
    out = []
    for batches in (16, 128) if smoke else (64, 512):
        d = os.path.join(scratch, f"wal_{batches}")
        s = StreamingIndex(
            BitmapIndex(packed, names, r=r),
            policy=CompactionPolicy(auto=False),
            durable_dir=d,
        )
        for _ in range(batches):
            c = int(rng.integers(0, n))
            p = rng.integers(0, r, 8)
            s.update(sets={names[c]: p[:4]}, clears={names[c]: p[4:]})
        t0 = time.perf_counter()
        rec = StreamingIndex.recover(d)
        t_recover = time.perf_counter() - t0
        assert rec.wal_version == s.wal_version
        out.append(
            {
                "wal_records": batches,
                "recover_us": t_recover * 1e6,
                "records_per_s": batches / t_recover,
                "wal_bytes": os.path.getsize(os.path.join(d, "wal.bmwal")),
            }
        )
    return out


def collect(smoke: bool = False) -> dict:
    with tempfile.TemporaryDirectory(prefix="persist_bench_") as scratch:
        return {
            "bench": "persist",
            "smoke": bool(smoke),
            "n_devices": len(jax.devices()),
            "snapshot_size": snapshot_size(smoke, scratch),
            "cold_load": cold_load(smoke, scratch),
            "wal_replay": wal_replay(smoke, scratch),
        }


def run(smoke: bool = False, payload: dict | None = None) -> list:
    if payload is None:
        payload = collect(smoke)
    out = []
    for row in payload["snapshot_size"]:
        out.append(
            (
                f"persist_size_d{row['density']}_bytes",
                row["snapshot_bytes"],
                f"{row['ratio']:.3f} of dense {row['dense_bytes']}B",
            )
        )
    for row in payload["cold_load"]:
        out.append(
            (
                f"persist_coldload_d{row['density']}_us",
                row["load_to_query_us"],
                f"rebuild {row['rebuild_to_query_us']:.0f}us",
            )
        )
        out.append(
            (
                f"persist_coldload_d{row['density']}_speedup",
                row["speedup"],
                row["target"],
            )
        )
    for row in payload["wal_replay"]:
        out.append(
            (
                f"persist_walreplay_{row['wal_records']}_rps",
                row["records_per_s"],
                f"{row['wal_bytes']}B log",
            )
        )
    return out


def write_json(path: str = "BENCH_persist.json", smoke: bool = False,
               payload: dict | None = None) -> dict:
    if payload is None:
        payload = collect(smoke)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return payload


if __name__ == "__main__":
    import sys

    smoke = "--smoke" in sys.argv
    payload = collect(smoke)
    for name, val, extra in run(smoke, payload=payload):
        print(f"{name},{val:.2f},{extra}")
    write_json(smoke=smoke, payload=payload)
    for row in payload["cold_load"]:
        print(
            f"density={row['density']}: load {row['load_to_query_us']:.0f}us vs "
            f"rebuild {row['rebuild_to_query_us']:.0f}us ({row['speedup']:.1f}x)"
        )
    print("wrote BENCH_persist.json")
