"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell:
    compute term    = HLO_FLOPs_per_device / peak_FLOPs          (197 TF/s bf16)
    memory term     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
    collective term = collective_bytes_per_device / link_bw      (50 GB/s/link)

The dry-run compiles a *partitioned* program, so cost_analysis numbers are
already per-device; dividing by per-chip peaks is equivalent to the global
form FLOPs / (chips x peak).  MODEL_FLOPS uses the 6ND / 2ND convention on
active params.  Caveat (documented in EXPERIMENTS.md): the CPU backend
upcasts bf16 dots to f32, so 'bytes accessed' overstates TPU HBM traffic by
up to 2x on matmul-heavy cells; FLOPs are dtype-independent.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12  # v5e bf16
HBM_BW = 819e9
LINK_BW = 50e9

_PCACHE: dict[str, int] = {}


def model_flops(arch: str, shape: str, n_devices: int) -> float:
    from repro.configs import get_config, shape_cells

    cfg = get_config(arch)
    if arch not in _PCACHE:
        _PCACHE[arch] = cfg.active_param_count()
    n_active = _PCACHE[arch]
    cell = shape_cells()[shape]
    b, s, kind = cell["global_batch"], cell["seq_len"], cell["kind"]
    if kind == "train":
        tokens, mult = b * s, 6
    elif kind == "prefill":
        tokens, mult = b * s, 2
    else:  # decode: one new token per sequence
        tokens, mult = b, 2
    return mult * n_active * tokens / n_devices


def analyze(artifact: dict) -> dict | None:
    if artifact.get("status") != "OK":
        return None
    la = artifact.get("loop_aware")
    if la:  # loop-aware accounting (scan bodies x trip counts) -- preferred
        flops = la["dot_flops"]
        byts = la["hbm_traffic_proxy"]
        coll = la["collective_total"]
    else:  # raw cost_analysis (undercounts while-loop bodies)
        flops = artifact["cost_analysis"].get("flops", 0.0)
        byts = artifact["cost_analysis"].get("bytes accessed", 0.0)
        coll = artifact["collectives"]["total_bytes"]
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": byts / HBM_BW,
        "collective_s": coll / LINK_BW,
    }
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    mf = model_flops(artifact["arch"], artifact["shape"], artifact["n_devices"])
    return {
        "arch": artifact["arch"],
        "shape": artifact["shape"],
        "mesh": artifact["mesh"],
        **{k: round(v * 1e3, 3) for k, v in terms.items()},  # ms
        "dominant": dominant.replace("_s", ""),
        "model_flops_per_dev": mf,
        "useful_flops_ratio": round(mf / flops, 3) if flops else 0.0,
        "roofline_fraction": round((mf / PEAK_FLOPS) / step_s, 3) if step_s else 0.0,
        "hlo_flops": flops,
        "hlo_bytes": byts,
        "coll_bytes": coll,
    }


def run(art_dir: str = "artifacts/dryrun"):
    out = []
    rows = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        rec = analyze(json.load(open(f)))
        if rec:
            rows.append(rec)
    for r in rows:
        tag = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        out.append(
            (
                tag,
                max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e3,
                f"dom={r['dominant']} frac={r['roofline_fraction']} "
                f"useful={r['useful_flops_ratio']}",
            )
        )
    return out, rows


def write_markdown(rows, path="artifacts/roofline.md"):
    hdr = (
        "| arch | shape | mesh | compute ms | memory ms | collective ms | dominant "
        "| MODEL/HLO flops | roofline frac |\n|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = [
        f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compute_s']} | {r['memory_s']} "
        f"| {r['collective_s']} | {r['dominant']} | {r['useful_flops_ratio']} "
        f"| {r['roofline_fraction']} |"
        for r in rows
    ]
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(hdr + "\n".join(lines) + "\n")
    return path


if __name__ == "__main__":
    res, rows = run()
    for name, val, extra in res:
        print(f"{name},{val:.3f},{extra}")
    print("wrote", write_markdown(rows))
