"""Query-layer benchmark: what the unified API buys.

  * composed expression compiled as ONE circuit (shared sideways-sum adder)
    vs leaf-at-a-time execution with a bitwise combine afterwards;
  * ``execute_many`` batching k independent queries into one jitted
    multi-output call vs k sequential calls;
  * compiled-circuit cache: cold (build + optimise + jit) vs warm hit.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.query import (
    And,
    BitmapIndex,
    Interval,
    Not,
    Parity,
    Threshold,
    clear_compiled_cache,
)


def _time(fn, reps=5):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run(smoke: bool = False):
    out = []
    rng = np.random.default_rng(0)
    n, nw = (16, 1 << 10) if smoke else (32, 1 << 14)
    bm = jnp.asarray(rng.integers(0, 2**32, (n, nw), dtype=np.uint32))
    idx = BitmapIndex(bm)

    q = And(Interval(2, 10), Not(Threshold(n - 2)))
    composed = _time(lambda: idx.execute(q, backend="circuit").block_until_ready())

    def leafwise():
        a = idx.execute(Interval(2, 10), backend="circuit")
        b = idx.execute(Threshold(n - 2), backend="ssum")
        return (a & ~b).block_until_ready()

    leaf = _time(leafwise)
    out.append(("query_composed_us", composed * 1e6, f"N={n} r={nw * 32}"))
    out.append(("query_leafwise_us", leaf * 1e6, "2 adder passes + combine"))
    out.append(("query_composed_speedup", leaf / composed, "one shared adder"))

    qs = [Threshold(t) for t in (2, n // 4, n // 2, n - 1)] + [Parity()]
    many = _time(lambda: [r.block_until_ready() for r in idx.execute_many(qs)])
    seq = _time(lambda: [idx.execute(x).block_until_ready() for x in qs])
    out.append(("query_batched_us", many * 1e6, f"{len(qs)} queries, one call"))
    out.append(("query_sequential_us", seq * 1e6, f"{len(qs)} separate executes"))

    clear_compiled_cache()
    t0 = time.perf_counter()
    idx.execute(q, backend="circuit").block_until_ready()
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    idx.execute(q, backend="circuit").block_until_ready()
    warm = time.perf_counter() - t0
    out.append(("query_compile_cold_ms", cold * 1e3, "build + optimise + jit"))
    out.append(("query_cached_warm_ms", warm * 1e3, "compiled-circuit cache hit"))
    return out


if __name__ == "__main__":
    for name, val, extra in run():
        print(f"{name},{val:.2f},{extra}")
