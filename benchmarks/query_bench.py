"""Query-layer benchmark: what the unified API + tiled storage engine buy.

  * composed expression compiled as ONE circuit (shared sideways-sum adder)
    vs leaf-at-a-time execution with a bitwise combine afterwards;
  * ``execute_many`` batching k independent queries into one jitted
    multi-output call vs k sequential calls;
  * compiled-circuit cache: cold (build + optimise + jit) vs warm hit;
  * clean-fraction sweep: dense fused kernel vs the storage engine's
    ``tiled_fused`` executor at clean fractions {0.0, 0.5, 0.9, 0.99} --
    wall time AND words touched (the roofline term), written to
    ``BENCH_query.json`` so CI tracks the perf trajectory;
  * shard-count sweep (1/2/4/8 row shards, mixed-density data): wall time +
    per-shard backend + per-shard words touched, so the trajectory captures
    scaling efficiency of the sharded engine, not just single-device
    numbers.  With >= 8 XLA devices the 8-shard point runs on a real mesh.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.query import (
    And,
    BitmapIndex,
    Interval,
    Not,
    Parity,
    Threshold,
    clear_compiled_cache,
)

CLEAN_FRACTIONS = (0.0, 0.5, 0.9, 0.99)
SHARD_COUNTS = (1, 2, 4, 8)
DENSITIES = (1e-4, 1e-3, 1e-2, 0.1, 0.5)


def _time(fn, reps=5):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def _clean_fraction_bits(n, n_tiles, clean_fraction, seed=0, span=64 * 32):
    rng = np.random.default_rng(seed)
    r = n_tiles * span
    bits = np.zeros((n, r), bool)
    for i in range(n):
        for tj in range(n_tiles):
            u = rng.random()
            lo, hi = tj * span, (tj + 1) * span
            if u < clean_fraction / 2:
                pass
            elif u < clean_fraction:
                bits[i, lo:hi] = True
            else:
                bits[i, lo:hi] = rng.random(span) < 0.35
    return bits


def clean_fraction_sweep(smoke: bool = False) -> list:
    """Dense fused vs tiled_fused: wall time + words touched per backend,
    PLUS the plan the cost model actually picks at each point -- the sweep
    otherwise cannot show whether the launch-overhead pricing steers
    production away from tiled_fused in the regimes where it loses on wall
    time (e.g. 3868us vs 80us fused at cf=0.5 in smoke data)."""
    n, n_tiles = (8, 8) if smoke else (16, 48)
    sweep = []
    for cf in CLEAN_FRACTIONS:
        bits = _clean_fraction_bits(n, n_tiles, cf, seed=int(cf * 100) + 1)
        idx = BitmapIndex.from_dense(jnp.asarray(bits))
        q = Threshold(n // 2)
        plan = idx.explain(q)  # what production would run at this point
        dense_words = idx.n * idx.n_words + idx.n_words  # N reads + 1 write
        t_fused = _time(
            lambda: idx.execute(q, backend="fused").block_until_ready()
        )
        t_tiled = _time(lambda: idx.execute(q, backend="tiled_fused"))
        info = idx.last_info
        tiled_words = info["dirty_words_gathered"] + idx.n_words
        sweep.append(
            {
                "clean_fraction": cf,
                "n": n,
                "n_words": idx.n_words,
                "planned": {
                    "algorithm": plan.algorithm,
                    "cost_words": plan.cost,
                    "candidates": [[b, c] for b, c in plan.candidates],
                },
                "backends": {
                    "fused": {
                        "wall_us": t_fused * 1e6,
                        "words_touched": dense_words,
                    },
                    "tiled_fused": {
                        "wall_us": t_tiled * 1e6,
                        "words_touched": tiled_words,
                        "case3_tiles": info["case3_tiles"],
                        "const_tiles": info["const_tiles"],
                        "signatures": info["signatures"],
                    },
                },
            }
        )
    return sweep


def sparsity_sweep(smoke: bool = False) -> list:
    """Column-density sweep 1e-4 .. 0.5: memory footprint and words touched
    per container kind, container store vs the legacy dense dirty pack.

    The query is the membership scan Threshold(1) (every dirty tile
    participates, so nothing hides behind case-1/2 folding) forced through
    ``tiled_fused`` on both stores -- the words-touched delta is purely the
    container representation.  The acceptance bar: >= 4x reduction at
    density <= 1e-3, no regression at 0.5 (where every container is dense
    and both stores are byte-identical).
    """
    n, n_tiles = (8, 8) if smoke else (16, 48)
    span = 64 * 32
    r = n_tiles * span
    q = Threshold(1)
    out = []
    for d in DENSITIES:
        rng = np.random.default_rng(int(1 / d) % 2**31)
        bits = rng.random((n, r)) < d
        idx = BitmapIndex.from_dense(jnp.asarray(bits))
        legacy = BitmapIndex.from_dense(jnp.asarray(bits), containers=False)
        t_cont = _time(lambda: idx.execute(q, backend="tiled_fused"))
        info = idx.last_info
        t_leg = _time(lambda: legacy.execute(q, backend="tiled_fused"))
        linfo = legacy.last_info
        words = info["dirty_words_gathered"] + idx.n_words
        words_legacy = linfo["dirty_words_gathered"] + idx.n_words
        out.append(
            {
                "density": d,
                "n": n,
                "n_words": idx.n_words,
                "dense_words": idx.n * idx.n_words + idx.n_words,
                "census": idx.store.container_census(),
                "memory_words": idx.store.storage_words(),
                "memory_words_legacy": legacy.store.storage_words(),
                "words_touched": words,
                "words_touched_legacy": words_legacy,
                "words_by_kind": info["words_by_kind"],
                "event_tiles": info["event_tiles"],
                "densified_tiles": info["densified_tiles"],
                # container pack reads vs the dense dirty pack's (the output
                # write pass is identical on both sides and excluded)
                "reduction": linfo["dirty_words_gathered"]
                / max(1, info["dirty_words_gathered"]),
                "wall_us": t_cont * 1e6,
                "wall_us_legacy": t_leg * 1e6,
            }
        )
    return out


def _mixed_density_bits(n, n_tiles, seed=0, span=64 * 32):
    """Half the row space dense (cf=0.0), half mostly clean (cf=0.95)."""
    rng = np.random.default_rng(seed)
    bits = np.zeros((n, n_tiles * span), bool)
    for i in range(n):
        for tj in range(n_tiles):
            lo, hi = tj * span, (tj + 1) * span
            if tj < n_tiles // 2:
                bits[i, lo:hi] = rng.random(span) < 0.35
            else:
                u = rng.random()
                if u < 0.475:
                    pass
                elif u < 0.95:
                    bits[i, lo:hi] = True
                else:
                    bits[i, lo:hi] = rng.random(span) < 0.35
    return bits


def shard_sweep(smoke: bool = False) -> list:
    """Row-shard scaling: wall time + per-shard backends + words touched."""
    n, n_tiles = (8, 8) if smoke else (16, 48)
    bits = _mixed_density_bits(n, n_tiles, seed=11)
    idx = BitmapIndex.from_dense(jnp.asarray(bits))
    q = Threshold(n // 2)
    out = []
    for s in SHARD_COUNTS:
        mesh = None
        if s == len(jax.devices()) > 1:
            from repro.launch.mesh import make_host_mesh

            mesh = make_host_mesh(data=s, model=1)
        sidx = idx.shard(mesh=mesh, n_shards=s)
        t = _time(lambda: sidx.execute(q).gather().block_until_ready())
        info = sidx.last_info
        per_shard = []
        if info["mode"] == "per_shard":
            for sh, be, inf in zip(
                sidx.store.shards, info["backends"], info["per_shard"]
            ):
                words = (
                    inf["dirty_words_gathered"] + sh.n_words
                    if inf is not None
                    else sh.n * sh.n_words + sh.n_words
                )
                per_shard.append({"backend": be, "words_touched": int(words)})
        out.append(
            {
                "n_shards": sidx.n_shards,
                "mesh": mesh is not None,
                "mode": info["mode"],
                "wall_us": t * 1e6,
                "backends": list(info["backends"]),
                "per_shard": per_shard,
            }
        )
    return out


def run(smoke: bool = False, sweep: list | None = None):
    out = []
    rng = np.random.default_rng(0)
    n, nw = (16, 1 << 10) if smoke else (32, 1 << 14)
    bm = jnp.asarray(rng.integers(0, 2**32, (n, nw), dtype=np.uint32))
    idx = BitmapIndex(bm)

    q = And(Interval(2, 10), Not(Threshold(n - 2)))
    composed = _time(lambda: idx.execute(q, backend="circuit").block_until_ready())

    def leafwise():
        a = idx.execute(Interval(2, 10), backend="circuit")
        b = idx.execute(Threshold(n - 2), backend="ssum")
        return (a & ~b).block_until_ready()

    leaf = _time(leafwise)
    out.append(("query_composed_us", composed * 1e6, f"N={n} r={nw * 32}"))
    out.append(("query_leafwise_us", leaf * 1e6, "2 adder passes + combine"))
    out.append(("query_composed_speedup", leaf / composed, "one shared adder"))

    qs = [Threshold(t) for t in (2, n // 4, n // 2, n - 1)] + [Parity()]
    many = _time(lambda: [r.block_until_ready() for r in idx.execute_many(qs)])
    seq = _time(lambda: [idx.execute(x).block_until_ready() for x in qs])
    out.append(("query_batched_us", many * 1e6, f"{len(qs)} queries, one call"))
    out.append(("query_sequential_us", seq * 1e6, f"{len(qs)} separate executes"))

    clear_compiled_cache()
    t0 = time.perf_counter()
    idx.execute(q, backend="circuit").block_until_ready()
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    idx.execute(q, backend="circuit").block_until_ready()
    warm = time.perf_counter() - t0
    out.append(("query_compile_cold_ms", cold * 1e3, "build + optimise + jit"))
    out.append(("query_cached_warm_ms", warm * 1e3, "compiled-circuit cache hit"))

    if sweep is None:
        sweep = clean_fraction_sweep(smoke)
    for row in sweep:
        cf = row["clean_fraction"]
        fused = row["backends"]["fused"]
        tiled = row["backends"]["tiled_fused"]
        out.append(
            (f"query_cf{cf}_fused_words", fused["words_touched"], "dense sweep")
        )
        out.append(
            (
                f"query_cf{cf}_tiled_words",
                tiled["words_touched"],
                f"{tiled['case3_tiles']} case-3 tiles",
            )
        )
        out.append((f"query_cf{cf}_fused_us", fused["wall_us"], ""))
        out.append((f"query_cf{cf}_tiled_us", tiled["wall_us"], ""))
        planned = row.get("planned")
        if planned:
            out.append(
                (
                    f"query_cf{cf}_planned_cost",
                    planned["cost_words"] or 0.0,
                    f"planner picks {planned['algorithm']}",
                )
            )
    return out


def write_json(path: str = "BENCH_query.json", smoke: bool = False,
               sweep: list | None = None, shards: list | None = None,
               sparsity: list | None = None) -> dict:
    """Write the perf-trajectory artifact consumed by CI."""
    payload = {
        "bench": "query",
        "smoke": bool(smoke),
        "n_devices": len(jax.devices()),
        "clean_fraction_sweep": sweep if sweep is not None else clean_fraction_sweep(smoke),
        "shard_sweep": shards if shards is not None else shard_sweep(smoke),
        "sparsity_sweep": sparsity if sparsity is not None else sparsity_sweep(smoke),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return payload


if __name__ == "__main__":
    import sys

    smoke = "--smoke" in sys.argv
    sweep = clean_fraction_sweep(smoke)  # measured once, printed + persisted
    shards = shard_sweep(smoke)
    sparsity = sparsity_sweep(smoke)
    for name, val, extra in run(smoke, sweep=sweep):
        print(f"{name},{val:.2f},{extra}")
    write_json(smoke=smoke, sweep=sweep, shards=shards, sparsity=sparsity)
    for row in sweep:
        be = row["backends"]
        print(
            f"cf={row['clean_fraction']}: fused {be['fused']['words_touched']} words, "
            f"tiled {be['tiled_fused']['words_touched']} words, "
            f"planner -> {row['planned']['algorithm']}"
        )
    for row in shards:
        print(
            f"shards={row['n_shards']} ({row['mode']}): {row['wall_us']:.0f} us, "
            f"backends {sorted(set(row['backends']))}"
        )
    for row in sparsity:
        c = row["census"]
        print(
            f"density={row['density']}: {row['words_touched']} words vs "
            f"{row['words_touched_legacy']} legacy ({row['reduction']:.1f}x), "
            f"mem {row['memory_words']}/{row['memory_words_legacy']} words, "
            f"census d/s/r={c['dense']}/{c['sparse']}/{c['run']}"
        )
    print("wrote BENCH_query.json")
