"""Tables 6/7/8: circuit gate counts vs the paper's published numbers.

This is the paper-faithfulness check: the sideways-sum construction must
reproduce the 'S. Sum' column of Table 8 EXACTLY; the tree adder matches
c(2^k) = 7N - 5 log2 N - 7 exactly at powers of two and is within 1% (our
constant propagation is slightly stronger) elsewhere; the Batcher sorter is
within ~10% (the paper prunes a hand-built merge network).
"""
from __future__ import annotations

from repro.core import circuits as C

TABLE8 = [
    # (N, T, tree_paper, ssum_paper, sorter_paper)
    (43, 30, 272, 192, 480),
    (85, 12, 562, 398, 1216),
    (120, 105, 806, 580, 1907),
    (323, 14, 2226, 1586, 7518),
    (329, 138, 2272, 1620, 9052),
    (330, 324, 2275, 1623, 7549),
    (786, 481, 5467, 3905, 28945),
    (786, 776, 5461, 3899, 24233),
]


def run():
    out = []
    ssum_exact = 0
    for n, t, tree_p, ssum_p, sort_p in TABLE8:
        tree = C.build_threshold_circuit(n, t, "treeadd").gate_count()
        ssum = C.build_threshold_circuit(n, t, "ssum").gate_count()
        srt = C.build_threshold_circuit(n, t, "srtckt").gate_count()
        ssum_exact += ssum == ssum_p
        out.append(
            (f"table8_N{n}_T{t}_ssum_gates", ssum, f"paper={ssum_p} exact={ssum == ssum_p}")
        )
        out.append((f"table8_N{n}_T{t}_tree_gates", tree, f"paper={tree_p}"))
        out.append((f"table8_N{n}_T{t}_sorter_gates", srt, f"paper={sort_p}"))
    out.append(("table8_ssum_exact_rows", ssum_exact, f"of {len(TABLE8)}"))
    for npow in (2, 4, 8, 16, 32):
        w = C.build_weight_circuit(npow, "treeadd").gate_count()
        out.append(
            (f"tree_c{npow}", w, f"formula={C.paper_tree_adder_gates(npow)}")
        )
    for npow, s_paper in [(2, 2), (4, 9), (8, 26), (16, 63), (32, 140)]:
        out.append(
            (f"ssum_s{npow}", C.build_weight_circuit(npow, "ssum").gate_count(),
             f"paper={s_paper}")
        )
    # Table 7 spot checks + LOOPED op-count formula
    for (n, t), e in {(4, 2): 9, (4, 3): 11, (5, 2): 12, (5, 3): 14}.items():
        out.append(
            (f"table7_N{n}_T{t}_ssum", C.build_threshold_circuit(n, t, "ssum").gate_count(),
             f"paper={e}")
        )
    for n, t in [(4, 3), (5, 2), (5, 4)]:
        out.append((f"looped_ops_N{n}_T{t}", C.looped_op_count(n, t), "formula"))
    return out


if __name__ == "__main__":
    for name, val, extra in run():
        print(f"{name},{val},{extra}")
