"""Table 10 / Figs 62-64: workload comparison across algorithm families.

A workload of similarity queries over the 5.3 synthetic datasets (uniform /
clustered x dense / moderate), run through: the bitmap circuit algorithms
(jnp + fused kernel), SCANCOUNT, the block-RLE RBMRG adaptation, and the
host-side integer-list competitors (WHEAP / MGOPT / WMGSK / DSK / W2CTI /
WSORT).  Reports total normalised time per algorithm (paper 5.9: each
dataset's fastest algorithm = 1.0) plus RBMRG's pruned work fraction.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import listalgos as LA
from repro.core.threshold import threshold
from repro.storage import TileStore, rbmrg_block_threshold
from repro.data.paper_datasets import similarity_query, synthetic_dataset

DATASETS = [
    ("uniform", "dense"),
    ("clustered", "dense"),
    ("uniform", "moderate"),
    ("clustered", "moderate"),
]
N, T = 32, 16


def _time(fn, reps=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run():
    out = []
    totals: dict[str, float] = {}
    for kind, dens in DATASETS:
        packed, r, lists = synthetic_dataset(kind, dens, n_bitmaps=64, card=3000, seed=1111)
        sel, rid = similarity_query(lists, N, seed=7)
        bm = jnp.asarray(packed[sel])
        sel_lists = [lists[i] for i in sel]
        stats = TileStore.from_packed(bm).block_stats()
        times = {}
        for alg in ("scancount", "looped", "ssum", "csvckt", "fused"):
            times[alg] = _time(lambda: threshold(bm, T, alg).block_until_ready())
        times["rbmrg_block"] = _time(lambda: rbmrg_block_threshold(bm, T, stats=stats))
        for name, fn in [
            ("wheap", LA.wheap), ("mgopt", LA.mgopt), ("wmgsk", LA.wmgsk),
            ("dsk", LA.dsk), ("w2cti", LA.w2cti), ("wsort", LA.wsort),
        ]:
            times[name] = _time(lambda fn=fn: fn(sel_lists, T, r))
        best = min(times.values())
        tag = f"{kind[:4]}_{dens[:3]}"
        for alg, dt in sorted(times.items(), key=lambda kv: kv[1]):
            norm = dt / best
            totals[alg] = totals.get(alg, 0.0) + norm
            out.append((f"table10_{tag}_{alg}", dt * 1e6, f"norm={norm:.2f}"))
        _, info = rbmrg_block_threshold(bm, T, stats=stats)
        out.append(
            (f"table10_{tag}_rbmrg_work_fraction", info["work_fraction"] * 100, "% of words")
        )
    for alg, tot in sorted(totals.items(), key=lambda kv: kv[1]):
        out.append((f"table10_total_norm_{alg}", tot, f"ideal={len(DATASETS)}"))
    return out


if __name__ == "__main__":
    for name, val, extra in run():
        print(f"{name},{val:.2f},{extra}")
